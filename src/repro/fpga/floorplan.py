"""ASCII floorplan rendering (the poor researcher's Vivado floorplanner).

Renders the repeating rectangle with hard-block columns and, optionally, the
conv units a placement assigns -- used by examples/quickstart.py to make the
decoded placements inspectable without any GUI tooling.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.core import genotype as G
from repro.fpga.device import ROWS_PER_CR, TYPE_NAMES
from repro.fpga.netlist import Problem

_GLYPH = {0: "U", 1: "D", 2: "B"}


def ascii_floorplan(problem: Problem, g: Optional[G.Genotype] = None,
                    width: int = 110, height: int = 40,
                    highlight_unit: Optional[int] = None) -> str:
    """Render columns ('.') and placed blocks (type glyph / unit digit)."""
    xs = np.concatenate([np.asarray(problem.geom[t].col_x) for t in G.TYPES])
    xmax = xs.max() * 1.02
    ymax = 2 * ROWS_PER_CR * 1.02
    grid = np.full((height, width), " ", dtype="<U1")

    for t in G.TYPES:
        for cx in np.asarray(problem.geom[t].col_x):
            cc = min(int(cx / xmax * width), width - 1)
            grid[:, cc] = "."

    if g is not None:
        bx, by = (np.asarray(a) for a in G.decode(problem, g))
        unit = problem.blk_unit
        for i in range(problem.n_blocks):
            r = height - 1 - min(int(by[i] / ymax * height), height - 1)
            c = min(int(bx[i] / xmax * width), width - 1)
            if highlight_unit is not None and unit[i] == highlight_unit:
                grid[r, c] = "#"
            else:
                grid[r, c] = _GLYPH[int(problem.blk_type[i])]

    legend = " | ".join(f"{_GLYPH[t]}={TYPE_NAMES[t]}" for t in G.TYPES)
    body = "\n".join("".join(row) for row in grid)
    return f"{body}\n[{problem.device_name}: {legend}; .=column site]"
