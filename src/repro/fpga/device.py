"""Xilinx UltraScale+ device models: columnar hard-block geometry.

RapidLayout places DSP48 / RAMB18 / URAM288 *cascade chains* onto the
irregular columnar fabric of UltraScale+ parts (VU3P..VU13P).  We model each
device as:

  * a set of hard-block columns per type, each with an RPM x coordinate and a
    site capacity (sites per column inside the minimum repeating rectangle),
  * a site->RPM-row pitch per type (24 DSP / 24 RAMB18 / 16 URAM per 60-row
    clock region),
  * the SLR / repeating-rectangle replication factors used by the paper's
    copy-paste flow (Fig. 5/6).

RAMB18 columns are modelled as *two parity sub-columns* (RAMB18_0 / RAMB18_1
interleave in one physical column, paper Eq. 5: cascade step Dy=+2).  A BRAM
cascade chain therefore occupies consecutive sites of one parity, and two
chains of opposite parity can interleave in the same physical column --
exactly the freedom the real cascade network provides.

Resource totals are calibrated so that the paper's published numbers fall out
exactly for the VU11P repeating rectangle (80 conv units, 100% URAM / 93.7%
DSP / 95.2% RAMB18 utilisation -- cf. paper SS III-C) and so that design sizes
match Table II (123/246/246/369/480/640 conv units for VU3P..VU13P).
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Dict, List, Tuple

import numpy as np

# type indices used everywhere downstream
URAM, DSP, BRAM = 0, 1, 2
TYPE_NAMES = ("URAM", "DSP", "BRAM")

# sites per 60-row clock region (UltraScale+ fabric constants)
SITES_PER_CR = {URAM: 16, DSP: 24, BRAM: 24}  # BRAM counted in RAMB18
ROWS_PER_CR = 60
# RPM row pitch per site (rows between vertically adjacent sites)
ROW_PITCH = {t: ROWS_PER_CR / SITES_PER_CR[t] for t in (URAM, DSP, BRAM)}

# cascade chain shapes of the conv unit (paper Fig. 1): dual 3x3 kernels
CHAIN_LEN = {URAM: 2, DSP: 9, BRAM: 4}
CHAINS_PER_UNIT = {URAM: 1, DSP: 2, BRAM: 2}
# cascade site step inside a chain (Eq. 5): +1 for DSP/URAM, +2 for RAMB18
SITE_STEP = {URAM: 1, DSP: 1, BRAM: 2}


def content_hash(*parts) -> str:
    """Stable short hex digest of a mixed array/scalar content tuple.

    Arrays hash by dtype + shape + raw bytes (C-contiguous), scalars by
    repr; the digest is independent of object identity and process, which
    is what makes it usable as a cross-process cache key (champion store,
    persisted JSON).  16 hex chars = 64 bits -- collision-safe for any
    realistic device/problem population.
    """
    h = hashlib.sha256()
    for p in parts:
        if isinstance(p, np.ndarray):
            a = np.ascontiguousarray(p)
            h.update(str(a.dtype).encode())
            h.update(str(a.shape).encode())
            h.update(a.tobytes())
        else:
            h.update(repr(p).encode())
        h.update(b"|")
    return h.hexdigest()[:16]


@dataclasses.dataclass(frozen=True, eq=False)
class ColumnSet:
    """All columns of one hard-block type inside the repeating rectangle."""

    x: np.ndarray          # [C] RPM x coordinate of each (sub)column
    cap_sites: np.ndarray  # [C] sites per (sub)column (chain-parity space)
    parity: np.ndarray     # [C] 0/1 row offset (BRAM sub-columns only)

    @property
    def n_cols(self) -> int:
        return int(self.x.shape[0])


@dataclasses.dataclass(frozen=True, eq=False)
class DeviceModel:
    """One UltraScale+ part, reduced to what placement needs."""

    name: str
    family: str                 # transfer-learning group: "A" (VU3P..9P) | "B"
    n_slr: int
    rects_per_slr: int
    units_per_rect: int         # conv units the repeating rectangle holds
    rect_rows: int              # rectangle height in RPM rows (2 clock regions)
    columns: Dict[int, ColumnSet]

    @property
    def units_total(self) -> int:
        return self.units_per_rect * self.rects_per_slr * self.n_slr

    @property
    def n_rects(self) -> int:
        return self.rects_per_slr * self.n_slr

    @property
    def signature(self) -> str:
        """Content hash of the full geometry (column x positions included).

        Two devices share a signature iff a placement found on one is a
        placement on the other -- the exact-match key of the champion
        store.  Name-independent: a renamed spec with identical geometry
        hashes the same.  Cached on first use (the model is frozen).
        """
        sig = self.__dict__.get("_signature")
        if sig is None:
            parts = [self.n_slr, self.rects_per_slr, self.units_per_rect,
                     self.rect_rows]
            for t in (URAM, DSP, BRAM):
                c = self.columns[t]
                parts += [c.x, c.cap_sites, c.parity]
            sig = content_hash(*parts)
            object.__setattr__(self, "_signature", sig)
        return sig

    @property
    def sibling_key(self) -> str:
        """Content hash of the *structural* geometry only (column counts,
        capacities, parities, chain demands -- NOT x positions or
        replication factors).  Devices sharing a sibling key present the
        same search space shape, so a champion migrates between them at
        high fidelity (`core.transfer.migrate`) -- the Table II pairs, and
        the sibling-match key of the champion store."""
        sig = self.__dict__.get("_sibling_key")
        if sig is None:
            parts = [self.units_per_rect]
            for t in (URAM, DSP, BRAM):
                c = self.columns[t]
                parts += [c.x.shape[0], c.cap_sites, c.parity]
            sig = content_hash(*parts)
            object.__setattr__(self, "_sibling_key", sig)
        return sig

    def chain_capacity(self, t: int) -> int:
        L = CHAIN_LEN[t]
        return int(np.sum(self.columns[t].cap_sites // L))

    def chains_needed(self, t: int) -> int:
        return self.units_per_rect * CHAINS_PER_UNIT[t]

    def utilization(self) -> Dict[str, float]:
        out = {}
        for t in (URAM, DSP, BRAM):
            used = self.chains_needed(t) * CHAIN_LEN[t]
            total = int(np.sum(self.columns[t].cap_sites))
            out[TYPE_NAMES[t]] = used / total
        return out


def _column_xs(n_uram: int, n_dsp: int, n_bram: int, seed: int,
               width: float = 680.0) -> Dict[int, np.ndarray]:
    """Synthesise an irregular interleave of hard-block columns.

    Real UltraScale+ fabrics interleave DSP/BRAM/URAM columns irregularly
    between CLB columns; the irregularity is what makes naive copy-paste
    placement illegal (paper SS III-C).  We reproduce that character with a
    device-seeded, deterministic layout: column order is a jittered
    round-robin, spacings are non-uniform in [6, 16] RPM x units.
    """
    rng = np.random.default_rng(seed)
    tags: List[int] = [URAM] * n_uram + [DSP] * n_dsp + [BRAM] * n_bram
    # deterministic shuffle -> irregular interleave, but keep it spread:
    # draw a jittered "ideal position" per column and sort.
    idx = np.concatenate([
        (np.arange(n_uram) + 0.5) / n_uram + rng.uniform(-.35, .35, n_uram) / n_uram,
        (np.arange(n_dsp) + 0.5) / n_dsp + rng.uniform(-.35, .35, n_dsp) / n_dsp,
        (np.arange(n_bram) + 0.5) / n_bram + rng.uniform(-.35, .35, n_bram) / n_bram,
    ])
    order = np.argsort(idx, kind="stable")
    gaps = rng.uniform(6.0, 16.0, size=len(tags))
    xs = np.cumsum(gaps)
    xs = xs / xs[-1] * width
    out = {URAM: [], DSP: [], BRAM: []}
    for pos, col in enumerate(order):
        out[tags[col]].append(xs[pos])
    return {t: np.asarray(v, np.float64) for t, v in out.items()}


def _make_device(name: str, family: str, n_slr: int, rects_per_slr: int,
                 units_per_rect: int, n_uram_cols: int, n_dsp_cols: int,
                 n_bram_cols: int, seed: int) -> DeviceModel:
    rect_rows = 2 * ROWS_PER_CR
    sites = {t: SITES_PER_CR[t] * 2 for t in (URAM, DSP, BRAM)}  # 2 CRs high
    xs = _column_xs(n_uram_cols, n_dsp_cols, n_bram_cols, seed)
    cols: Dict[int, ColumnSet] = {}
    for t in (URAM, DSP):
        cols[t] = ColumnSet(
            x=xs[t],
            cap_sites=np.full(len(xs[t]), sites[t], np.int64),
            parity=np.zeros(len(xs[t]), np.int64),
        )
    # BRAM columns split into two parity sub-columns of half the sites each
    bx = np.repeat(xs[BRAM], 2)
    bcap = np.full(len(bx), sites[BRAM] // 2, np.int64)
    bpar = np.tile(np.array([0, 1], np.int64), len(xs[BRAM]))
    cols[BRAM] = ColumnSet(x=bx, cap_sites=bcap, parity=bpar)
    dev = DeviceModel(name=name, family=family, n_slr=n_slr,
                      rects_per_slr=rects_per_slr, units_per_rect=units_per_rect,
                      rect_rows=rect_rows, columns=cols)
    for t in (URAM, DSP, BRAM):
        need, cap = dev.chains_needed(t), dev.chain_capacity(t)
        if need > cap:
            raise ValueError(
                f"{name}: {TYPE_NAMES[t]} chain capacity {cap} < required {need}")
    return dev


# ----------------------------------------------------------------------------
# The UltraScale+ family (design sizes per paper Table II).
#
# Family "A" rect (VU3P..VU9P): 123 conv units / SLR, 1 rect per SLR.
#   URAM: 123 chains (246 sites)  ->  8 cols x 32 sites  (96.1% util)
#   DSP : 246 chains x 9 = 2214   -> 50 cols x 48 sites  (92.3% util)
#   BRAM: 246 chains x 4 =  984   -> 21 cols x 48 sites  (97.6% util)
# Family "B" rect (VU11P/VU13P): 80 conv units, 2 rects per SLR.
#   URAM: 80 chains (160 sites)   ->  5 cols x 32 sites  (100%  util)
#   DSP : 160 chains x 9 = 1440   -> 32 cols x 48 sites  (93.75% util)
#   BRAM: 160 chains x 4 =  640   -> 14 cols x 48 sites  (95.2% util)
# The family-B numbers reproduce the paper's reported rectangle utilisation
# (100% URAM / 93.7% DSP / 95.2% BRAM) exactly, and VU11P totals come out to
# the full-chip 960 URAM / 9216 DSP / 4032 RAMB18.
# ----------------------------------------------------------------------------
_SPECS = {
    "xcvu3p":  dict(family="A", n_slr=1, rects_per_slr=1, units_per_rect=123,
                    n_uram_cols=8, n_dsp_cols=50, n_bram_cols=21, seed=103),
    "xcvu5p":  dict(family="A", n_slr=2, rects_per_slr=1, units_per_rect=123,
                    n_uram_cols=8, n_dsp_cols=50, n_bram_cols=21, seed=105),
    "xcvu7p":  dict(family="A", n_slr=2, rects_per_slr=1, units_per_rect=123,
                    n_uram_cols=8, n_dsp_cols=50, n_bram_cols=21, seed=107),
    "xcvu9p":  dict(family="A", n_slr=3, rects_per_slr=1, units_per_rect=123,
                    n_uram_cols=8, n_dsp_cols=50, n_bram_cols=21, seed=109),
    "xcvu11p": dict(family="B", n_slr=3, rects_per_slr=2, units_per_rect=80,
                    n_uram_cols=5, n_dsp_cols=32, n_bram_cols=14, seed=111),
    "xcvu13p": dict(family="B", n_slr=4, rects_per_slr=2, units_per_rect=80,
                    n_uram_cols=5, n_dsp_cols=32, n_bram_cols=14, seed=113),
}

# small synthetic parts for tests / quickstart: 6 conv units.  The second
# is a geometry *sibling* of the first (same column counts and capacities,
# different seeded column layout) -- the cheap analogue of a VU3P->VU5P
# transfer pair for warm-start tests and the CI bench smoke.
_SPECS["xcvu_test"] = dict(family="T", n_slr=1, rects_per_slr=1,
                           units_per_rect=6, n_uram_cols=2, n_dsp_cols=4,
                           n_bram_cols=2, seed=7)
_SPECS["xcvu_test2"] = dict(family="T", n_slr=1, rects_per_slr=1,
                            units_per_rect=6, n_uram_cols=2, n_dsp_cols=4,
                            n_bram_cols=2, seed=8)


def get_device(name: str) -> DeviceModel:
    if name not in _SPECS:
        raise KeyError(f"unknown device {name!r}; have {sorted(_SPECS)}")
    return _make_device(name=name, **_SPECS[name])


def list_devices() -> Tuple[str, ...]:
    return tuple(sorted(_SPECS))
