"""Conv-unit netlist reconstruction (paper Fig. 1) + static placement problem.

Each convolution unit C_k (dual 3x3 kernels, URAM-bandwidth matched) contains

    1 URAM cascade chain  of length 2   (u0 feed, u1 collect)
    2 DSP  cascade chains of length 9   (one per 3x3 kernel, accumulators cascaded)
    2 BRAM cascade chains of length 4   (row-reuse line buffers)

for the paper's 2 URAM + 18 DSP + 8 RAMB18 per unit.  Cascade links are hard
wires (zero routing cost) -- they are *constraints*, not nets.  The routed
nets we reconstruct (weights = modelled connection counts, bits = bus widths
used by the pipelining register model):

    u0 -> bA0 / bB0     w=4  bits=72   URAM feeds both line-buffer chains
    dA8 / dB8 -> u1     w=4  bits=48   accumulator tails write back to URAM
    u0 -> dA0 / dB0     w=2  bits=9    control / address fanout
    bXj -> dX(2j)(+1)   w=2  bits=18   line buffers feed DSP pairs
    bX3 -> dX8          w=2  bits=18   last buffer also feeds the 9th DSP
    u1[k] -> u0[k+1]    w=2  bits=72   inter-unit systolic URAM chain

The exact w_ij of Samajdar et al. [27] are unpublished; these reconstructions
preserve the paper's structure and land the pipelining register model in the
paper's 256K-323K chip-wide range (EXPERIMENTS.md SSPaper-fidelity).

The static `Problem` bundles device geometry + netlist into padded numpy
arrays that the JAX genotype decoder / objective kernels close over.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import numpy as np

from repro.fpga.device import (BRAM, CHAIN_LEN, CHAINS_PER_UNIT, DSP,
                               ROW_PITCH, SITE_STEP, URAM, DeviceModel,
                               content_hash)

# roles inside one conv unit, in logical-gid order
# (u0,u1 | dA0..dA8 | dB0..dB8 | bA0..bA3 | bB0..bB3)  -> 28 blocks
BLOCKS_PER_UNIT = 28
_ROLE_LAYOUT = (
    (URAM, 0, 2),   # (type, chain_role_within_unit, chain_len)
    (DSP, 0, 9),
    (DSP, 1, 9),
    (BRAM, 0, 4),
    (BRAM, 1, 4),
)


def _unit_gid(unit: int, role_slot: int, offset: int) -> int:
    """Global logical block id for (unit, role slot in _ROLE_LAYOUT, offset)."""
    base = unit * BLOCKS_PER_UNIT
    off = 0
    for slot, (_, _, ln) in enumerate(_ROLE_LAYOUT):
        if slot == role_slot:
            return base + off + offset
        off += ln
    raise ValueError(role_slot)


def build_nets(n_units: int) -> Tuple[np.ndarray, np.ndarray, np.ndarray,
                                      np.ndarray]:
    """Return (src_gid, dst_gid, weight, bits) arrays for an n_units design."""
    src: List[int] = []
    dst: List[int] = []
    w: List[float] = []
    bits: List[int] = []

    def add(s: int, d: int, ww: float, bb: int) -> None:
        src.append(s)
        dst.append(d)
        w.append(ww)
        bits.append(bb)

    for k in range(n_units):
        u0 = _unit_gid(k, 0, 0)
        u1 = _unit_gid(k, 0, 1)
        for chain_slot, dsp_slot in ((3, 1), (4, 2)):    # (bram slot, dsp slot)
            b0 = _unit_gid(k, chain_slot, 0)
            add(u0, b0, 4.0, 72)                          # URAM -> line buffers
            d_tail = _unit_gid(k, dsp_slot, 8)
            add(d_tail, u1, 4.0, 48)                      # accum tail -> URAM
            d0 = _unit_gid(k, dsp_slot, 0)
            add(u0, d0, 2.0, 9)                           # control / address
            for j in range(4):
                bj = _unit_gid(k, chain_slot, j)
                add(bj, _unit_gid(k, dsp_slot, 2 * j), 2.0, 18)
                add(bj, _unit_gid(k, dsp_slot, 2 * j + 1), 2.0, 18)
            add(_unit_gid(k, chain_slot, 3), _unit_gid(k, dsp_slot, 8), 2.0, 18)
        if k + 1 < n_units:                               # inter-unit systolic
            add(u1, _unit_gid(k + 1, 0, 0), 2.0, 72)

    return (np.asarray(src, np.int32), np.asarray(dst, np.int32),
            np.asarray(w, np.float32), np.asarray(bits, np.int32))


@dataclasses.dataclass(frozen=True, eq=False)
class TypeGeom:
    """Static per-type geometry, padded for fixed-shape JAX decode."""

    col_x: np.ndarray        # [C] f32 RPM x per (sub)column
    col_cap_chains: np.ndarray  # [C] i32 chain slots per (sub)column
    col_parity: np.ndarray   # [C] i32 row offset of site 0 (BRAM parity)
    chain_len: int
    site_step: int           # rows-in-site-index between chain members
    row_pitch: float         # RPM rows per site index unit
    n_chains: int            # chains the design needs (fixed)

    @property
    def n_cols(self) -> int:
        return int(self.col_x.shape[0])

    @property
    def max_chains_per_col(self) -> int:
        return int(self.col_cap_chains.max())


@dataclasses.dataclass(frozen=True, eq=False)
class Problem:
    """Static placement problem: device geometry x replicated netlist.

    Everything here is numpy (host constants closed over by jitted code);
    only genotypes are traced JAX values.
    """

    device_name: str
    n_units: int
    geom: Tuple[TypeGeom, TypeGeom, TypeGeom]   # indexed by URAM/DSP/BRAM
    # netlist over logical gids
    net_src: np.ndarray
    net_dst: np.ndarray
    net_w: np.ndarray
    net_bits: np.ndarray
    # gid -> (type, logical chain, offset) flattening tables
    blk_type: np.ndarray
    blk_chain: np.ndarray
    blk_off: np.ndarray
    blk_unit: np.ndarray
    # gid -> position in concat-per-type flattened coords (see decoder)
    blk_flatpos: np.ndarray
    n_rects: int            # full-chip replication factor (copy-paste flow)

    @property
    def n_blocks(self) -> int:
        return int(self.blk_type.shape[0])

    @property
    def n_nets(self) -> int:
        return int(self.net_src.shape[0])

    @property
    def signature(self) -> str:
        """Content hash of (geometry x netlist): the exact identity of this
        placement problem.  Equal signatures mean a genotype is directly
        reusable (identity transfer); the champion store's primary key.
        Cached on first use -- problems are frozen.
        """
        sig = self.__dict__.get("_signature")
        if sig is None:
            parts = [self.n_units, self.n_rects]
            for g in self.geom:
                parts += [g.col_x, g.col_cap_chains, g.col_parity,
                          g.chain_len, g.site_step, g.row_pitch, g.n_chains]
            parts += [self.net_src, self.net_dst, self.net_w, self.net_bits]
            sig = content_hash(*parts)
            object.__setattr__(self, "_signature", sig)
        return sig

    @property
    def sibling_key(self) -> str:
        """Content hash of the structural shape only: column counts,
        capacities, parities, chain demands and the netlist -- NOT column x
        positions or the chip replication factor.  Problems sharing a
        sibling key have the same genotype sizes and netlist, so a
        champion projects between them at high fidelity
        (`core.transfer.migrate`) -- how the champion store discovers
        warm-start donors across devices."""
        sig = self.__dict__.get("_sibling_key")
        if sig is None:
            parts = [self.n_units]
            for g in self.geom:
                parts += [g.col_x.shape[0], g.col_cap_chains, g.col_parity,
                          g.chain_len, g.site_step, g.row_pitch, g.n_chains]
            parts += [self.net_src, self.net_dst, self.net_w, self.net_bits]
            sig = content_hash(*parts)
            object.__setattr__(self, "_sibling_key", sig)
        return sig

    def genotype_sizes(self) -> Dict[str, Tuple[int, ...]]:
        g = self.geom
        return {
            "dist": tuple(g[t].n_cols for t in (URAM, DSP, BRAM)),
            "loc": tuple(g[t].n_chains for t in (URAM, DSP, BRAM)),
            "map": tuple(g[t].n_chains for t in (URAM, DSP, BRAM)),
        }

    @property
    def continuous_dim(self) -> int:
        """Dimension of the flat continuous encoding (CMA-ES / SA)."""
        s = self.genotype_sizes()
        return sum(s["dist"]) + sum(s["loc"]) + sum(s["map"])


def make_problem(dev: DeviceModel) -> Problem:
    n_units = dev.units_per_rect
    geoms = []
    for t in (URAM, DSP, BRAM):
        cs = dev.columns[t]
        geoms.append(TypeGeom(
            col_x=cs.x.astype(np.float32),
            col_cap_chains=(cs.cap_sites // CHAIN_LEN[t]).astype(np.int32),
            col_parity=cs.parity.astype(np.int32),
            chain_len=CHAIN_LEN[t],
            site_step=SITE_STEP[t],
            row_pitch=float(ROW_PITCH[t]),
            n_chains=n_units * CHAINS_PER_UNIT[t],
        ))
    src, dst, w, bits = build_nets(n_units)

    # gid flattening tables
    n_blocks = n_units * BLOCKS_PER_UNIT
    blk_type = np.empty(n_blocks, np.int32)
    blk_chain = np.empty(n_blocks, np.int32)
    blk_off = np.empty(n_blocks, np.int32)
    blk_unit = np.empty(n_blocks, np.int32)
    for k in range(n_units):
        gid = k * BLOCKS_PER_UNIT
        for (t, role, ln) in _ROLE_LAYOUT:
            chain = k * CHAINS_PER_UNIT[t] + role
            for off in range(ln):
                blk_type[gid] = t
                blk_chain[gid] = chain
                blk_off[gid] = off
                gid += 1
        blk_unit[k * BLOCKS_PER_UNIT:(k + 1) * BLOCKS_PER_UNIT] = k

    # position of each gid in the per-type concatenated [N_t * L_t] layout
    bases = {}
    acc = 0
    for t in (URAM, DSP, BRAM):
        bases[t] = acc
        acc += geoms[t].n_chains * geoms[t].chain_len
    blk_flatpos = np.array(
        [bases[int(blk_type[g])]
         + int(blk_chain[g]) * geoms[int(blk_type[g])].chain_len
         + int(blk_off[g]) for g in range(n_blocks)], np.int32)

    return Problem(
        device_name=dev.name, n_units=n_units,
        geom=(geoms[0], geoms[1], geoms[2]),
        net_src=src, net_dst=dst, net_w=w, net_bits=bits,
        blk_type=blk_type, blk_chain=blk_chain, blk_off=blk_off,
        blk_unit=blk_unit, blk_flatpos=blk_flatpos,
        n_rects=dev.n_rects,
    )
