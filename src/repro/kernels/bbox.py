"""Pallas TPU kernel: population-batched max-bounding-box reduction (Eq. 2).

Input: block coordinates grouped per conv unit, laid out [P, B, U]
(population, blocks-per-unit on sublanes, units on lanes) so the unit axis --
the long one -- rides the 128-wide lane dimension.  Each grid step reduces a
(BP, B, BU) tile: min/max over the block axis, width+height per unit, max
over the unit tile, then max-accumulates into out[p].

Padding contract (enforced by ops.py): padded *units* replicate a real
column of coordinates (bbox 0 -> neutral under max); padded *blocks*
replicate block 0 of their unit (neutral under min/max).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import _padding as P

BP, BU = 8, 128
NEG = -3.4e38


def _kernel(ux, uy, o_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.full_like(o_ref, NEG)

    x = ux[...].astype(jnp.float32)
    y = uy[...].astype(jnp.float32)
    w = jnp.max(x, axis=1) - jnp.min(x, axis=1)       # [BP, BU]
    h = jnp.max(y, axis=1) - jnp.min(y, axis=1)
    o_ref[...] = jnp.maximum(o_ref[...], jnp.max(w + h, axis=1))


@functools.partial(jax.jit, static_argnames=("interpret",))
def maxbbox_pallas(ux: jnp.ndarray, uy: jnp.ndarray,
                   interpret: bool = False) -> jnp.ndarray:
    """ux, uy: [P, U, B] -> [P] fp32 max over units of (w + h)."""
    p, u, b = ux.shape
    # lay out as [P, B, U]; replicate-pad blocks to a sublane multiple
    ux = jnp.swapaxes(ux, 1, 2)
    uy = jnp.swapaxes(uy, 1, 2)
    ux, uy = P.pad_unit_blocks(ux, uy, 8, BU)
    # edge-pad the population rows too: replicated rows are sliced off
    ux = P.pad_multiple(ux, 0, BP, mode="edge")
    uy = P.pad_multiple(uy, 0, BP, mode="edge")
    pp, pu, bb = ux.shape[0] - p, ux.shape[2] - u, ux.shape[1] - b
    grid = ((p + pp) // BP, (u + pu) // BU)
    spec = pl.BlockSpec((BP, b + bb, BU), lambda i, j: (i, 0, j))
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[spec, spec],
        out_specs=pl.BlockSpec((BP,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct(((p + pp),), jnp.float32),
        interpret=interpret,
    )(ux, uy)
    return out[:p]
