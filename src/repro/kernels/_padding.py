"""Padding contracts shared by the placement evaluation kernels.

Every Pallas kernel in this package tiles fixed (sublane x lane) blocks over
inputs whose real extents are arbitrary, so each wrapper pads up to tile
multiples.  The padding must be *neutral under the kernel's reduction* --
a padded element contributing anything would silently corrupt results for
exactly the shapes that cross a tile boundary.  These helpers centralise
the contracts (re-exported by `kernels.ops`, unit-tested directly in
`tests/test_fused_eval.py`):

  * **nets** (weighted-sum reduction, Eq. 1): padded nets carry ``w == 0``
    so their squared length contributes 0.  Endpoint *values* pad with
    zeros (`pad_net_endpoints`); endpoint *indices* pad with gid 0
    (`pad_net_indices`) -- any in-range gid is safe once the weight is 0.
  * **units / blocks** (min/max reduction, Eq. 2): padded blocks replicate
    a real block of their unit (neutral under min/max); padded units
    replicate a real unit -- or, in the fused gather layout
    (`pad_unit_index`), point every block at gid 0, a degenerate unit of
    bbox exactly 0, neutral under the final max because every real bbox
    is >= 0.
  * **population rows** (batch axis): padded rows compute garbage that the
    wrapper slices off; zeros keep the arithmetic finite.
  * **domination rows** (`pad_objs_inf`): padded candidates sit at +inf on
    every objective, so they dominate nothing (their matrix columns are
    sliced off; their matrix rows and count contributions are all zero).
"""
from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp


def pad_multiple(a: jnp.ndarray, axis: int, mult: int,
                 mode: str = "zero") -> jnp.ndarray:
    """Pad `axis` of `a` up to the next multiple of `mult`.

    mode="zero" appends zeros (for padding sliced off or weighted out);
    mode="edge" replicates the boundary element (neutral under min/max).
    """
    extra = -a.shape[axis] % mult
    if extra == 0:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, extra)
    if mode == "edge":
        return jnp.pad(a, widths, mode="edge")
    return jnp.pad(a, widths)


def pad_pop(a: jnp.ndarray, bp: int) -> jnp.ndarray:
    """Zero-pad the leading population/batch axis; callers slice off the
    padded rows, so their (finite) garbage is never observed."""
    return pad_multiple(a, 0, bp, mode="zero")


def pad_net_endpoints(x1, y1, x2, y2, w, bn: int
                      ) -> Tuple[jnp.ndarray, ...]:
    """Pad the net axis (last) of endpoint-value arrays to a `bn` multiple.

    Contract: padded nets have weight 0, so ``((|dx|+|dy|) * w)^2 == 0``
    regardless of the (zero) coordinates -- neutral under the Eq. 1 sum.
    """
    return (pad_multiple(x1, -1, bn), pad_multiple(y1, -1, bn),
            pad_multiple(x2, -1, bn), pad_multiple(y2, -1, bn),
            pad_multiple(w, -1, bn))


def pad_net_indices(src, dst, w, bn: int, n_tiles: int = 0
                    ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Pad gather-index nets for the fused layout: indices to gid 0 (any
    in-range gid is safe), weights to 0 (the neutrality guarantee).

    `n_tiles` (optional) forces at least that many bn-tiles so the net
    grid can share an axis with the unit grid (`fused_eval`)."""
    n = src.shape[-1]
    total = max(-(-n // bn), n_tiles) * bn
    return (pad_multiple(src, -1, total), pad_multiple(dst, -1, total),
            pad_multiple(w, -1, total))


def pad_unit_blocks(ux, uy, bb: int, bu: int
                    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Replicate-pad [..., B, U] unit-grouped coordinates (bbox layout).

    Padded blocks (axis -2) replicate the boundary block of their unit --
    neutral under per-unit min/max; padded units (axis -1) replicate the
    boundary unit, whose bbox is a real unit's bbox -- neutral under the
    final max."""
    ux = pad_multiple(pad_multiple(ux, -2, bb, "edge"), -1, bu, "edge")
    uy = pad_multiple(pad_multiple(uy, -2, bb, "edge"), -1, bu, "edge")
    return ux, uy


def pad_unit_index(uidx: jnp.ndarray, bu: int, bb: int = 8,
                   n_tiles: int = 0) -> jnp.ndarray:
    """Pad a [U, B] unit gather table for the fused layout.

    Padded blocks (axis 1) replicate the unit's last block (duplicate
    coordinates never move a min/max); padded units (axis 0) point every
    block at gid 0 -- a degenerate unit whose bbox is exactly 0, neutral
    under the final max because every real bbox is >= 0.  `n_tiles`
    forces at least that many bu-tiles (shared grid with the net axis).
    """
    uidx = pad_multiple(uidx, 1, bb, mode="edge")
    u = uidx.shape[0]
    total = max(-(-u // bu), n_tiles) * bu
    if total > u:
        fill = jnp.zeros((total - u, uidx.shape[1]), uidx.dtype)
        uidx = jnp.concatenate([uidx, fill], axis=0)
    return uidx


def pad_objs_inf(objs: jnp.ndarray, bi: int) -> jnp.ndarray:
    """Pad a [P, M] objective table with +inf rows for the domination
    kernels: a +inf candidate is dominated by everything and dominates
    nothing, so padded rows add 0 to every dominated-by count."""
    return jnp.pad(objs.astype(jnp.float32),
                   ((0, -objs.shape[0] % bi), (0, 0)),
                   constant_values=jnp.inf)
