"""Memory-bounded flash attention in pure XLA (scan over KV blocks).

This is the production attention path on non-TPU backends and the lowering
used by the CPU dry-run: it never materialises the [S, T] score matrix --
peak intermediate is [B, H, S, block_k] -- so 32k-token prefill compiles
with sane memory_analysis numbers.  Semantics identical to
`ref.flash_attention_ref` (tested); on TPU `ops.flash_attention` swaps in
the Pallas kernel instead.

`banded` is the sub-quadratic sliding-window variant: scan over *query*
chunks, each attending only to its (window + block_q)-wide KV band via
dynamic_slice -- FLOPs ~ S * window instead of S^2 (gemma3 local layers;
see EXPERIMENTS.md SSPerf for the roofline delta it buys).
"""
from __future__ import annotations

import functools
import os
from typing import Optional

import jax
import jax.numpy as jnp

NEG = -1e30

# SSPerf hillclimb knobs (read once at import; dryrun sets them per cell):
#   REPRO_FLASH_BLOCK_K : kv-block size of the online-softmax scan
#   REPRO_FLASH_PV_BF16 : compute the p @ v inner product in bf16 (the
#     [B,H,S,BK] probability tile is the dominant HBM tensor on the XLA
#     path; bf16 halves its traffic, m/l stats stay fp32)
ENV_BLOCK_K = int(os.environ.get("REPRO_FLASH_BLOCK_K", "512"))
PV_BF16 = os.environ.get("REPRO_FLASH_PV_BF16", "0") == "1"


def _gqa(h: int, hkv: int) -> int:
    assert h % hkv == 0, (h, hkv)
    return h // hkv


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_k"))
def flash_attention_xla(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                        causal: bool = True, window: Optional[int] = None,
                        block_k: int = ENV_BLOCK_K) -> jnp.ndarray:
    """q: [B,H,S,D]; k,v: [B,Hkv,T,D] -> [B,H,S,D].  Online softmax over
    KV blocks; checkpointed block body keeps bwd memory at one block."""
    b, h, s, d = q.shape
    hkv, t = k.shape[1], k.shape[2]
    g = _gqa(h, hkv)
    bk = min(block_k, t)
    pt = -t % bk
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, pt), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, pt), (0, 0)))
    nblk = (t + pt) // bk
    scale = 1.0 / (d ** 0.5)
    qf = q.astype(jnp.float32) * scale
    q_pos = jnp.arange(s) + (t - s)

    # reshape kv blocks to scan over: [nblk, B, Hkv, bk, D]
    kb = jnp.moveaxis(kp.reshape(b, hkv, nblk, bk, d), 2, 0)
    vb = jnp.moveaxis(vp.reshape(b, hkv, nblk, bk, d), 2, 0)

    @jax.checkpoint
    def block(carry, inp):
        m, l, acc = carry
        jblk, kblk, vblk = inp
        kx = jnp.repeat(kblk, g, axis=1).astype(jnp.float32)  # [B,H,bk,D]
        vx = jnp.repeat(vblk, g, axis=1).astype(jnp.float32)
        sc = jnp.einsum("bhsd,bhtd->bhst", qf, kx)
        k_pos = jblk * bk + jnp.arange(bk)
        mask = (k_pos < t)[None, :]
        if causal:
            mask = mask & (k_pos[None, :] <= q_pos[:, None])
        if window is not None:
            mask = mask & (k_pos[None, :] > q_pos[:, None] - window)
        sc = jnp.where(mask[None, None], sc, NEG)
        m_new = jnp.maximum(m, jnp.max(sc, axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(sc - m_new[..., None])
        l_new = l * alpha + jnp.sum(p, axis=-1)
        if PV_BF16:
            pv = jnp.einsum("bhst,bhtd->bhsd", p.astype(jnp.bfloat16),
                            vx.astype(jnp.bfloat16)).astype(jnp.float32)
        else:
            pv = jnp.einsum("bhst,bhtd->bhsd", p, vx)
        acc_new = acc * alpha[..., None] + pv
        return (m_new, l_new, acc_new), None

    init = (jnp.full((b, h, s), NEG, jnp.float32),
            jnp.zeros((b, h, s), jnp.float32),
            jnp.zeros((b, h, s, d), jnp.float32))
    (m, l, acc), _ = jax.lax.scan(
        block, init, (jnp.arange(nblk), kb, vb))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.astype(q.dtype)


@functools.partial(jax.jit, static_argnames=("window", "block_q"))
def banded_attention_xla(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                         window: int, block_q: int = 512) -> jnp.ndarray:
    """Causal sliding-window attention, sub-quadratic: each query chunk
    attends a KV band of width (window - 1 + block_q) ending at its last
    position.  Self-attention only (S == T)."""
    b, h, s, d = q.shape
    hkv, t = k.shape[1], k.shape[2]
    assert s == t, "banded path is for self-attention"
    g = _gqa(h, hkv)
    bq = min(block_q, s)
    ps = -s % bq
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, ps), (0, 0)))
    nblk = (s + ps) // bq
    band = window - 1 + bq
    # pad keys on the left so every band slice is in range
    kp = jnp.pad(k, ((0, 0), (0, 0), (band, ps), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (band, ps), (0, 0)))
    scale = 1.0 / (d ** 0.5)

    qb = jnp.moveaxis(qp.reshape(b, h, nblk, bq, d), 2, 0)

    @jax.checkpoint
    def chunk(_, inp):
        i, qblk = inp
        # band covers absolute kv positions [i*bq + bq - 1 - (band-1), i*bq+bq)
        start = i * bq + bq - 1 - (band - 1) + band   # index into padded kp
        kband = jax.lax.dynamic_slice_in_dim(kp, start, band, axis=2)
        vband = jax.lax.dynamic_slice_in_dim(vp, start, band, axis=2)
        kx = jnp.repeat(kband, g, axis=1).astype(jnp.float32)
        vx = jnp.repeat(vband, g, axis=1).astype(jnp.float32)
        sc = jnp.einsum("bhsd,bhtd->bhst",
                        qblk.astype(jnp.float32) * scale, kx)
        q_pos = i * bq + jnp.arange(bq)
        k_pos = (start - band) + jnp.arange(band)
        mask = ((k_pos[None, :] <= q_pos[:, None])
                & (k_pos[None, :] > q_pos[:, None] - window)
                & (k_pos[None, :] >= 0) & (q_pos[:, None] < s))
        sc = jnp.where(mask[None, None], sc, NEG)
        p = jax.nn.softmax(sc, axis=-1)
        out = jnp.einsum("bhst,bhtd->bhsd", p, vx)
        return None, out.astype(q.dtype)

    _, outs = jax.lax.scan(chunk, None, (jnp.arange(nblk), qb))
    out = jnp.moveaxis(outs, 0, 2).reshape(b, h, s + ps, d)
    return out[:, :, :s]
