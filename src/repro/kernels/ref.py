"""Pure-jnp oracles for every Pallas kernel in this package.

These are the semantics of record: each Pallas kernel's test sweeps shapes and
dtypes and asserts allclose against the function here.  On CPU (this
container, and any host without TPUs) `ops.py` dispatches to these directly,
so the whole framework runs identically -- just without the VMEM tiling.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


# ----------------------------------------------------------- EA objectives

def wirelength2_ref(x1: jnp.ndarray, y1: jnp.ndarray, x2: jnp.ndarray,
                    y2: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Paper Eq. 1: sum_n ((|dx_n| + |dy_n|) * w_n)^2.

    Inputs are per-net endpoint coordinates, shape [..., N]; reduces the last
    axis.  fp32 accumulation.
    """
    dl = (jnp.abs(x1 - x2) + jnp.abs(y1 - y2)) * w
    return jnp.sum(dl.astype(jnp.float32) ** 2, axis=-1)


def net_lengths_ref(x1, y1, x2, y2) -> jnp.ndarray:
    """Per-net Manhattan wirelength, shape-preserving (pipelining input)."""
    return jnp.abs(x1 - x2) + jnp.abs(y1 - y2)


def maxbbox_ref(ux: jnp.ndarray, uy: jnp.ndarray) -> jnp.ndarray:
    """Paper Eq. 2: max_k BBoxSize(C_k), BBox = width + height.

    ux, uy: [..., U, B] block coordinates grouped per conv unit; reduces the
    last two axes to the max over units of (max-min)x + (max-min)y.
    """
    w = jnp.max(ux, axis=-1) - jnp.min(ux, axis=-1)
    h = jnp.max(uy, axis=-1) - jnp.min(uy, axis=-1)
    return jnp.max(w + h, axis=-1)


def fused_eval_ref(bx: jnp.ndarray, by: jnp.ndarray, src: jnp.ndarray,
                   dst: jnp.ndarray, w: jnp.ndarray, uidx: jnp.ndarray
                   ) -> jnp.ndarray:
    """Oracle for the fused evaluation kernel.

    bx, by: [..., G] decoded block coordinates; src/dst/w: [N] nets; uidx:
    [U, B] unit gather table.  Returns [..., 2] fp32 = (wl^2, max bbox).
    Deliberately composed from the per-objective oracles so the fused path
    inherits their (tested) semantics exactly -- on CPU, `ops.fused_eval`
    dispatching here is arithmetically identical to the unfused dispatch.
    """
    wl2 = wirelength2_ref(bx[..., src], by[..., src],
                          bx[..., dst], by[..., dst], w)
    bb = maxbbox_ref(bx[..., uidx], by[..., uidx])
    return jnp.stack([wl2, bb], axis=-1)


def domination_ref(objs: jnp.ndarray) -> jnp.ndarray:
    """Pareto domination matrix for minimisation.

    objs: [P, M].  Returns bool [P, P]; out[i, j] == True iff i dominates j
    (all objectives <=, at least one <).
    """
    a = objs[:, None, :]   # i
    b = objs[None, :, :]   # j
    return jnp.all(a <= b, axis=-1) & jnp.any(a < b, axis=-1)


# ------------------------------------------------------------- attention

def _gqa_expand(k: jnp.ndarray, n_q_heads: int) -> jnp.ndarray:
    """[B, Hkv, T, D] -> [B, H, T, D] by repeating each KV head."""
    b, hkv, t, d = k.shape
    rep = n_q_heads // hkv
    return jnp.repeat(k, rep, axis=1)


def flash_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                        causal: bool = True,
                        window: Optional[int] = None,
                        logit_soft_cap: Optional[float] = None
                        ) -> jnp.ndarray:
    """Reference attention.  q: [B,H,S,D]; k,v: [B,Hkv,T,D] (GQA).

    causal masking assumes queries are the *last* S positions of the T-long
    key sequence (covers both self-attention S==T and decode S==1, T==cache).
    `window` (if set) keeps only keys within `window` positions behind the
    query (sliding-window attention, gemma3-style local layers).
    """
    orig_dtype = q.dtype
    b, h, s, d = q.shape
    t = k.shape[2]
    k = _gqa_expand(k, h)
    v = _gqa_expand(v, h)
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, jnp.float32))
    logits = jnp.einsum("bhsd,bhtd->bhst", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if logit_soft_cap is not None:
        logits = logit_soft_cap * jnp.tanh(logits / logit_soft_cap)
    q_pos = jnp.arange(s) + (t - s)
    k_pos = jnp.arange(t)
    mask = jnp.ones((s, t), bool)
    if causal:
        mask &= k_pos[None, :] <= q_pos[:, None]
    if window is not None:
        mask &= k_pos[None, :] > q_pos[:, None] - window
    logits = jnp.where(mask[None, None], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhst,bhtd->bhsd", probs, v.astype(jnp.float32))
    return out.astype(orig_dtype)


def decode_attention_ref(q: jnp.ndarray, k_cache: jnp.ndarray,
                         v_cache: jnp.ndarray, cache_len: jnp.ndarray
                         ) -> jnp.ndarray:
    """Single-token decode attention against a (possibly padded) KV cache.

    q: [B, H, D]; caches: [B, Hkv, T, D]; cache_len: [B] valid lengths.
    """
    b, h, d = q.shape
    t = k_cache.shape[2]
    k = _gqa_expand(k_cache, h).astype(jnp.float32)
    v = _gqa_expand(v_cache, h).astype(jnp.float32)
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, jnp.float32))
    logits = jnp.einsum("bhd,bhtd->bht", q.astype(jnp.float32), k) * scale
    valid = jnp.arange(t)[None, :] < cache_len[:, None]
    logits = jnp.where(valid[:, None, :], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bht,bhtd->bhd", probs, v)
    return out.astype(q.dtype)
