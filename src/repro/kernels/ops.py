"""Dispatching wrappers around the Pallas kernels.

Policy: on TPU backends the Pallas kernels run compiled; everywhere else the
`ref.py` oracles run (identical semantics, XLA-fused).  Setting
``REPRO_PALLAS=interpret`` forces the Pallas path in interpret mode -- used by
the test suite to execute the kernel bodies on CPU.

`flash_attention` carries a custom VJP whose backward pass recomputes from
the jnp reference -- the standard memory-saving flash recompute, keeping the
fwd kernel and autodiff consistent by construction.
"""
from __future__ import annotations

import functools
import os
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as _np

from repro.kernels import bbox as _bbox
from repro.kernels import domination as _dom
from repro.kernels import flash_attention as _fa
from repro.kernels import fused_eval as _fe
from repro.kernels import ref as _ref
from repro.kernels import wirelength as _wl
from repro.kernels import xla_flash as _xf
from repro.kernels._padding import (  # noqa: F401  (re-exported contracts)
    pad_multiple, pad_net_endpoints, pad_net_indices, pad_objs_inf,
    pad_pop, pad_unit_blocks, pad_unit_index,
)


def _mode() -> str:
    env = os.environ.get("REPRO_PALLAS", "auto")
    if env == "interpret":
        return "interpret"
    if env == "ref":
        return "ref"
    return "tpu" if jax.default_backend() == "tpu" else "ref"


def wirelength2(x1, y1, x2, y2, w) -> jnp.ndarray:
    """[..., N] endpoint coords -> [...] fp32 (Eq. 1)."""
    m = _mode()
    if m == "ref":
        return _ref.wirelength2_ref(x1, y1, x2, y2, w)
    fn = functools.partial(_wl.wirelength2_pallas, interpret=(m == "interpret"))
    if x1.ndim == 1:
        return fn(*(a[None] for a in (x1, y1, x2, y2, w)))[0]
    return fn(x1, y1, x2, y2, w)


def maxbbox(ux, uy) -> jnp.ndarray:
    """[..., U, B] unit-grouped coords -> [...] fp32 (Eq. 2)."""
    m = _mode()
    if m == "ref":
        return _ref.maxbbox_ref(ux, uy)
    fn = functools.partial(_bbox.maxbbox_pallas, interpret=(m == "interpret"))
    if ux.ndim == 2:
        return fn(ux[None], uy[None])[0]
    return fn(ux, uy)


def domination_matrix(objs: jnp.ndarray) -> jnp.ndarray:
    """[P, M] objectives -> bool [P, P], minimisation domination."""
    m = _mode()
    if m == "ref" or objs.shape[-1] != 2:
        return _ref.domination_ref(objs)
    return _dom.domination_pallas(
        objs, interpret=(m == "interpret")).astype(bool)


def fused_eval(bx, by, src, dst, w, uidx) -> jnp.ndarray:
    """Fused Eq. 1 + Eq. 2 over decoded coordinates.

    bx, by: [..., G] (arbitrary leading batch: slots x islands x pop);
    src/dst/w: [N] nets; uidx: [U, B] unit gather table.  Returns
    [..., 2] fp32 = (wirelength^2, max bbox).  One kernel launch for the
    whole stacked service batch -- endpoint/unit tensors never hit HBM.
    """
    m = _mode()
    if m == "ref":
        # decode order is unit-major, so `core.objectives.unit_index` is
        # the identity table; gathering by it selects exactly the reshape
        # elements, so the reshape is bitwise the gather -- take the free
        # one on the oracle path (concrete tables only: a traced uidx
        # falls through to the gather).
        try:
            ident = _np.array_equal(
                _np.asarray(uidx),
                _np.arange(uidx.size).reshape(uidx.shape))
        except jax.errors.TracerArrayConversionError:
            ident = False
        if ident:
            u, b = uidx.shape
            ux = bx.reshape(*bx.shape[:-1], u, b)
            uy = by.reshape(*by.shape[:-1], u, b)
            wl2 = _ref.wirelength2_ref(bx[..., src], by[..., src],
                                       bx[..., dst], by[..., dst], w)
            return jnp.stack([wl2, _ref.maxbbox_ref(ux, uy)], axis=-1)
        return _ref.fused_eval_ref(bx, by, src, dst, w, uidx)
    return _fe.fused_eval_pallas(bx, by, src, dst, w, uidx,
                                 interpret=(m == "interpret"))


def fused_domination_counts(objs: jnp.ndarray
                            ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """[P, M] objectives -> (bool dom [P, P], int32 dominated-by [P]).

    Fuses the NSGA-II domination matrix with its column reduction so the
    counts never round-trip the [P, P] matrix through HBM.
    """
    m = _mode()
    if m == "ref" or objs.shape[-1] != 2:
        dom = _ref.domination_ref(objs)
        return dom, jnp.sum(dom.astype(jnp.int32), axis=0)
    dom, cnt = _fe.domination_counts_pallas(
        objs, interpret=(m == "interpret"))
    return dom.astype(bool), cnt


# ------------------------------------------------------------- attention

@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def flash_attention(q, k, v, causal: bool = True,
                    window: Optional[int] = None,
                    logit_soft_cap: Optional[float] = None):
    """q: [B,H,S,D]; k,v: [B,Hkv,T,D] -> [B,H,S,D]."""
    m = _mode()
    if logit_soft_cap is not None:
        # soft-cap variant only exists on the ref path (none of the assigned
        # archs enable it at the kernel level)
        return _ref.flash_attention_ref(q, k, v, causal=causal, window=window,
                                        logit_soft_cap=logit_soft_cap)
    if m == "ref":
        # memory-bounded XLA path: never materialises [S, T]
        return _xf.flash_attention_xla(q, k, v, causal=causal, window=window)
    return _fa.flash_attention_pallas(q, k, v, causal=causal, window=window,
                                      interpret=(m == "interpret"))


def _fa_fwd(q, k, v, causal, window, logit_soft_cap):
    out = flash_attention(q, k, v, causal, window, logit_soft_cap)
    return out, (q, k, v)


def _fa_bwd(causal, window, logit_soft_cap, res, g):
    q, k, v = res
    _, vjp = jax.vjp(
        lambda q_, k_, v_: _ref.flash_attention_ref(
            q_, k_, v_, causal=causal, window=window,
            logit_soft_cap=logit_soft_cap), q, k, v)
    return vjp(g)


flash_attention.defvjp(_fa_fwd, _fa_bwd)


def decode_attention(q, k_cache, v_cache, cache_len) -> jnp.ndarray:
    """Single-token decode (no kernel: one GEMV per head, XLA path)."""
    return _ref.decode_attention_ref(q, k_cache, v_cache, cache_len)
