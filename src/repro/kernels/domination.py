"""Pallas TPU kernel: pairwise Pareto-domination matrix for NSGA-II.

Non-dominated sorting needs, every generation, the P x P boolean matrix
  dom[i, j] = (f(i) <= f(j) elementwise) and (f(i) < f(j) somewhere).
For the paper's two objectives (wirelength^2, max bbox) this unrolls to four
broadcast compares per tile.  Objectives arrive as two row/column vectors so
tiles are rank-2 (BI, 1) x (1, BJ) -> (BI, BJ) int8 -- a pure-VPU outer
product walk over the population grid.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import _padding as P

BI, BJ = 128, 128


def _kernel(a0, a1, b0, b1, o_ref):
    ra0, ra1 = a0[...], a1[...]          # (BI, 1)
    cb0, cb1 = b0[...], b1[...]          # (1, BJ)
    le = (ra0 <= cb0) & (ra1 <= cb1)
    lt = (ra0 < cb0) | (ra1 < cb1)
    o_ref[...] = (le & lt).astype(jnp.int8)


@functools.partial(jax.jit, static_argnames=("interpret",))
def domination_pallas(objs: jnp.ndarray, interpret: bool = False
                      ) -> jnp.ndarray:
    """objs: [P, 2] fp32 -> int8 [P, P]; out[i,j]=1 iff i dominates j."""
    p = objs.shape[0]
    # +inf rows dominate nothing; padded cols are sliced off
    o = P.pad_objs_inf(objs, BI)
    o0r = o[:, 0:1]                       # [P, 1]
    o1r = o[:, 1:2]
    o0c = o[:, 0].reshape(1, -1)          # [1, P]
    o1c = o[:, 1].reshape(1, -1)
    n = o.shape[0]
    grid = (n // BI, n // BJ)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BI, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((BI, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((1, BJ), lambda i, j: (0, j)),
            pl.BlockSpec((1, BJ), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((BI, BJ), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, n), jnp.int8),
        interpret=interpret,
    )(o0r, o1r, o0c, o1c)
    return out[:p, :p]
