"""Pallas TPU kernel: population-batched squared-wirelength reduction.

The EA's hot loop evaluates Eq. 1 for a whole population every generation:
given gathered per-net endpoint coordinates [P, N] (population x nets), fuse

    dl = (|x1-x2| + |y1-y2|) * w ;  out[p] = sum_n dl^2

into one VMEM-tiled pass -- no [P, N] intermediate ever hits HBM.  The grid
walks (population tiles, net tiles); the net axis is innermost so each output
tile is revisited and accumulated in place (TPU sequential-grid guarantee).

Tiling: BP x BN = 8 x 512 fp32 tiles -> 5 inputs * 16 KiB = 80 KiB VMEM per
step, MXU-free pure-VPU workload, lane dim 512 = 4x128 registers.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import _padding as P

BP, BN = 8, 512


def _kernel(x1, y1, x2, y2, w, o_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    dl = (jnp.abs(x1[...] - x2[...]) + jnp.abs(y1[...] - y2[...])) * w[...]
    dl = dl.astype(jnp.float32)
    o_ref[...] += jnp.sum(dl * dl, axis=1)


@functools.partial(jax.jit, static_argnames=("interpret",))
def wirelength2_pallas(x1: jnp.ndarray, y1: jnp.ndarray, x2: jnp.ndarray,
                       y2: jnp.ndarray, w: jnp.ndarray,
                       interpret: bool = False) -> jnp.ndarray:
    """x*, y*, w: [P, N] -> [P] fp32.  Pads internally; w==0 on padding."""
    p, n = x1.shape
    x1, y1, x2, y2, w = P.pad_net_endpoints(x1, y1, x2, y2, w, BN)
    x1, y1, x2, y2, w = (P.pad_pop(a, BP) for a in (x1, y1, x2, y2, w))
    pp, pn = x1.shape[0] - p, x1.shape[1] - n
    grid = ((p + pp) // BP, (n + pn) // BN)
    spec = pl.BlockSpec((BP, BN), lambda i, j: (i, j))
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[spec] * 5,
        out_specs=pl.BlockSpec((BP,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct(((p + pp),), jnp.float32),
        interpret=interpret,
    )(x1, y1, x2, y2, w)
    return out[:p]
