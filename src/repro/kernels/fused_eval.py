"""Pallas TPU kernel: fused placement evaluation for the full service batch.

The separate `wirelength` / `bbox` kernels each re-read the decoded
coordinates from HBM after the host has materialised per-net endpoint
arrays ([P, N] x 4) and per-unit coordinate tensors ([P, B, U] x 2).  For
the stacked (slots x islands x pop) batch the service evaluates every
step, those gathers dominate the memory traffic: ~6x the coordinate bytes
move through HBM before a single flop of Eq. 1 / Eq. 2 runs.

This kernel keeps one coordinate row-block resident in VMEM and performs
the gathers *inside* the grid step:

    coords cx, cy : [P, G]   (population x gids, decode order)
    nets src, dst : [N] int32 gather indices into G, weights w : [N]
    units uidx    : [U, B] int32 gather table (block b of unit u -> gid)

    grid (i, j) = (population tiles, max(net tiles, unit tiles))
      step: wl[i] += sum_n ((|x[s]-x[d]| + |y[s]-y[d]|) * w)^2   (net tile j)
            bb[i]  = max(bb[i], max_u (max-min)x + (max-min)y)   (unit tile j)

The j axis is innermost, so both (BP,) output tiles are revisited on
consecutive grid steps (TPU sequential-grid accumulation guarantee); step
j == 0 initialises wl to 0 and bb to -inf.  Net and unit tile counts are
padded up to a *shared* j extent with neutral elements (see
`kernels._padding`): surplus net tiles carry w == 0, surplus unit rows
gather the degenerate gid-0 unit whose bbox is exactly 0.

A second kernel fuses the NSGA-II domination matrix with its column
reduction (dominated-by counts), saving the [P, P] int32 round-trip that
`nondominated_rank` otherwise pays before its peeling loop.

Like every kernel here, `ops.py` dispatches to the `ref.py` oracle off-TPU;
interpret mode executes these bodies on CPU for the differential sweeps.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import _padding as P

BP = 8          # population sublane tile
BN = 512        # nets per grid step (lane dim, 4x128)
BU = 128        # units per grid step (lane dim)
NEG = -3.4e38


def _eval_kernel(cx, cy, src, dst, w, uidx, wl_ref, bb_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        wl_ref[...] = jnp.zeros_like(wl_ref)
        bb_ref[...] = jnp.full_like(bb_ref, NEG)

    x = cx[...].astype(jnp.float32)                  # [BP, G]
    y = cy[...].astype(jnp.float32)

    # Eq. 1 partial: gather this tile's net endpoints from the resident row
    s, d = src[0], dst[0]                            # (BN,) int32
    dl = (jnp.abs(jnp.take(x, s, axis=1) - jnp.take(x, d, axis=1))
          + jnp.abs(jnp.take(y, s, axis=1) - jnp.take(y, d, axis=1)))
    dl = dl * w[0].astype(jnp.float32)               # padded nets: w == 0
    wl_ref[...] += jnp.sum(dl * dl, axis=1)

    # Eq. 2 partial: gather this tile's unit blocks, bbox, max-accumulate
    u = uidx[...]                                    # (BU, Bp) int32
    gx = jnp.take(x, u.reshape(-1), axis=1).reshape(x.shape[0], *u.shape)
    gy = jnp.take(y, u.reshape(-1), axis=1).reshape(y.shape[0], *u.shape)
    wd = jnp.max(gx, axis=2) - jnp.min(gx, axis=2)   # [BP, BU]
    ht = jnp.max(gy, axis=2) - jnp.min(gy, axis=2)
    bb_ref[...] = jnp.maximum(bb_ref[...], jnp.max(wd + ht, axis=1))


@functools.partial(jax.jit, static_argnames=("interpret",))
def fused_eval_pallas(cx: jnp.ndarray, cy: jnp.ndarray, src: jnp.ndarray,
                      dst: jnp.ndarray, w: jnp.ndarray, uidx: jnp.ndarray,
                      interpret: bool = False) -> jnp.ndarray:
    """cx, cy: [..., G]; src/dst/w: [N]; uidx: [U, B] -> [..., 2] fp32.

    Column 0 is wirelength^2 (Eq. 1), column 1 max bbox (Eq. 2).  Leading
    batch axes (slots x islands x pop) are flattened into one population
    axis -- the whole service batch is a single grid.
    """
    batch = cx.shape[:-1]
    g = cx.shape[-1]
    cx = cx.reshape(-1, g)
    cy = cy.reshape(-1, g)
    p = cx.shape[0]

    # shared j extent: enough tiles for both the net and the unit walk
    n_tiles = max(-(-src.shape[-1] // BN), -(-uidx.shape[0] // BU))
    src, dst, w = P.pad_net_indices(src, dst, w, BN, n_tiles)
    uidx = P.pad_unit_index(uidx, BU, bb=8, n_tiles=n_tiles)
    cx = P.pad_pop(P.pad_multiple(cx, -1, 128), BP)
    cy = P.pad_pop(P.pad_multiple(cy, -1, 128), BP)
    pp, gp = cx.shape
    bp_u = uidx.shape[1]

    grid = (pp // BP, n_tiles)
    out_spec = pl.BlockSpec((BP,), lambda i, j: (i,))
    wl, bb = pl.pallas_call(
        _eval_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BP, gp), lambda i, j: (i, 0)),     # cx
            pl.BlockSpec((BP, gp), lambda i, j: (i, 0)),     # cy
            pl.BlockSpec((1, BN), lambda i, j: (0, j)),      # src
            pl.BlockSpec((1, BN), lambda i, j: (0, j)),      # dst
            pl.BlockSpec((1, BN), lambda i, j: (0, j)),      # w
            pl.BlockSpec((BU, bp_u), lambda i, j: (j, 0)),   # uidx
        ],
        out_specs=[out_spec, out_spec],
        out_shape=[jax.ShapeDtypeStruct((pp,), jnp.float32)] * 2,
        interpret=interpret,
    )(cx, cy, src.reshape(1, -1).astype(jnp.int32),
      dst.reshape(1, -1).astype(jnp.int32),
      w.reshape(1, -1), uidx.astype(jnp.int32))
    return jnp.stack([wl[:p], bb[:p]], axis=-1).reshape(*batch, 2)


# --------------------------------------------- fused domination + counts

BI, BJ = 128, 128


def _dom_kernel(a0, a1, b0, b1, dom_ref, cnt_ref):
    i = pl.program_id(1)

    @pl.when(i == 0)
    def _init():
        cnt_ref[...] = jnp.zeros_like(cnt_ref)

    ra0, ra1 = a0[...], a1[...]          # (BI, 1)  rows: candidate i
    cb0, cb1 = b0[...], b1[...]          # (1, BJ)  cols: candidate j
    le = (ra0 <= cb0) & (ra1 <= cb1)
    lt = (ra0 < cb0) | (ra1 < cb1)
    d = le & lt
    dom_ref[...] = d.astype(jnp.int8)
    cnt_ref[...] += jnp.sum(d.astype(jnp.int32), axis=0)


@functools.partial(jax.jit, static_argnames=("interpret",))
def domination_counts_pallas(objs: jnp.ndarray, interpret: bool = False
                             ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """objs: [P, 2] -> (dom int8 [P, P], dominated-by counts int32 [P]).

    Same tiling as `domination.domination_pallas`, but the row axis i is
    the *inner* grid dim so each (BJ,) count tile is revisited on
    consecutive steps and the column sum never leaves VMEM.
    """
    p = objs.shape[0]
    o = P.pad_objs_inf(objs, BI)
    n = o.shape[0]
    o0r, o1r = o[:, 0:1], o[:, 1:2]
    o0c, o1c = o[:, 0].reshape(1, -1), o[:, 1].reshape(1, -1)
    grid = (n // BJ, n // BI)            # (j cols outer, i rows inner)
    dom, cnt = pl.pallas_call(
        _dom_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BI, 1), lambda j, i: (i, 0)),
            pl.BlockSpec((BI, 1), lambda j, i: (i, 0)),
            pl.BlockSpec((1, BJ), lambda j, i: (0, j)),
            pl.BlockSpec((1, BJ), lambda j, i: (0, j)),
        ],
        out_specs=[
            pl.BlockSpec((BI, BJ), lambda j, i: (i, j)),
            pl.BlockSpec((BJ,), lambda j, i: (j,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, n), jnp.int8),
            jax.ShapeDtypeStruct((n,), jnp.int32),
        ],
        interpret=interpret,
    )(o0r, o1r, o0c, o1c)
    return dom[:p, :p], cnt[:p]
