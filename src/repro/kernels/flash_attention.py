"""Pallas TPU kernel: causal flash attention (FA-2 schedule), GQA-aware.

Layout targets the MXU: q tiles (BQ=128, D) x k tiles (BK=128, D) feed
128x128 systolic matmuls; the online-softmax running state (m, l, acc) lives
in VMEM scratch and is carried across the innermost kv-block grid axis
(TPU sequential-grid guarantee).  GQA is handled in the index map: the kv
block for query head h is h // group -- no KV replication in HBM.

Supports: causal masking for self-attention (S == T) and chunked decode
(S < T, queries are the last S positions), sliding-window masking
(gemma3-style local layers), tail padding on both S and T.

The backward pass is deliberately an XLA recompute (see ops.flash_attention):
dq/dk/dv from the jnp reference under `jax.vjp`.  Numerics of record are
ref.flash_attention_ref; tests sweep shapes/dtypes in interpret mode.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BQ = 128
DEFAULT_BK = 128
NEG_INF = -1e30


def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                scale: float, causal: bool, window: Optional[int],
                s_real: int, t_real: int, bq: int, bk: int):
    i = pl.program_id(2)
    j = pl.program_id(3)
    nj = pl.num_programs(3)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale          # [BQ, D]
    k = k_ref[0, 0].astype(jnp.float32)                  # [BK, D]
    v = v_ref[0, 0].astype(jnp.float32)                  # [BK, D]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # [BQ, BK]

    q_pos = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0) \
        + (t_real - s_real)
    k_pos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = k_pos < t_real                                # tail padding
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    alpha = jnp.exp(m_prev - m_new)                      # <= 1, no NaN: both
    p = jnp.exp(s - m_new[:, None])                      # finite via NEG_INF
    l_new = l_ref[...] * alpha + jnp.sum(p, axis=1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(j == nj - 1)
    def _finish():
        l = l_ref[...]
        safe = jnp.where(l > 0.0, l, 1.0)
        o_ref[0, 0] = (acc_ref[...] / safe[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("causal", "window", "interpret",
                                    "block_q", "block_k"))
def flash_attention_pallas(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                           causal: bool = True,
                           window: Optional[int] = None,
                           interpret: bool = False,
                           block_q: int = DEFAULT_BQ,
                           block_k: int = DEFAULT_BK) -> jnp.ndarray:
    """q: [B,H,S,D]; k,v: [B,Hkv,T,D] -> [B,H,S,D]."""
    b, h, s_real, d = q.shape
    hkv, t_real = k.shape[1], k.shape[2]
    assert h % hkv == 0, (h, hkv)
    group = h // hkv
    bq = min(block_q, max(8, s_real))
    bk = min(block_k, max(8, t_real))
    ps = -s_real % bq
    pt = -t_real % bk
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, ps), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, pt), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, pt), (0, 0)))
    grid = (b, h, (s_real + ps) // bq, (t_real + pt) // bk)

    kern = functools.partial(
        _fwd_kernel, scale=1.0 / (d ** 0.5), causal=causal, window=window,
        s_real=s_real, t_real=t_real, bq=bq, bk=bk)
    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda b_, h_, i, j: (b_, h_, i, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda b_, h_, i, j: (b_, h_ // group, j, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda b_, h_, i, j: (b_, h_ // group, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d),
                               lambda b_, h_, i, j: (b_, h_, i, 0)),
        out_shape=jax.ShapeDtypeStruct(qp.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, d), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
        ],
        interpret=interpret,
    )(qp, kp, vp)
    return out[:, :, :s_real, :]
