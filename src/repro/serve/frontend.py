"""Asyncio job front-end: submit / stream-progress / cancel over a
background stepping thread.

Until PR 9 the serve layer was hand-pumped: callers owned the stepping
loop (`while sched.busy: sched.step()`), so a process serving concurrent
clients had to invent its own threading, its own admission control, and
its own job-state plumbing.  `PlacementFrontend` is that missing layer:

  * **one stepping thread** -- the front-end owns a daemon thread that is
    the ONLY code touching the wrapped `PlacementScheduler`.  Client
    coroutines talk to it through a command queue (submit / cancel), and
    it talks back by resolving `serve.api.JobHandle`s and scheduling
    wake-ups onto the event loop (`loop.call_soon_threadsafe`).  Because
    every scheduler call -- admission, stepping, cancellation -- happens
    on that one thread, cancels land *between* `step()` calls, i.e.
    exactly at the step boundary the slot contract requires, and the
    single-step-compile / zero-blocking-compile-grow invariants are
    untouched (compiles just happen on the stepping thread, where
    `runtime.compile_cache.CompileMeter` scopes them per-thread already).
  * **bounded admission with backpressure** -- at most `max_queue` jobs
    may be outstanding (submitted, not yet terminal).  `await submit()`
    suspends the *caller* when the bound is hit and resumes it as slots
    drain; `submit_nowait()` raises `serve.api.QueueFull` instead.  The
    stepping thread never blocks on admission and a slow progress
    consumer never blocks the stepping thread (bounded per-handle ring).
  * **streaming progress** -- after every scheduler step the front-end
    pushes a `ProgressUpdate` (generation, best objectives, metric) into
    each running job's handle, adding an `eta_s` extrapolated from that
    job's own generation throughput; consume with
    `async for update in handle.progress()`.
  * **graceful shutdown** -- `drain()` stops admission and waits for
    every outstanding job to finish; `aclose()` drains, joins the
    stepping thread, then `scheduler.close()` (persist the champion
    store, stop the prewarm worker).  `async with PlacementFrontend(...)`
    does both ends.

Correctness contract: the front-end adds *concurrency*, never *state* --
per-job results remain pure functions of (config, seed, budget,
init_state) because the scheduler underneath is stepped exactly as a
synchronous caller would step it, just from another thread.  Submission
order, backpressure stalls, cancellations of co-tenant jobs and progress
consumers change latency only (verified by the concurrent-vs-sequential
determinism test in `tests/test_frontend.py`).

Typical use::

    sched = PlacementScheduler(n_slots=4, store=store, prewarm=True)
    async with PlacementFrontend(sched, max_queue=32) as fe:
        handle = await fe.submit(JobRequest(device="xcvu3p-quad",
                                            cfg=cfg, seed=7, budget=64))
        async for update in handle.progress():
            print(update.gens, update.metric, update.eta_s)
        result = await handle.wait()
"""
from __future__ import annotations

import asyncio
import collections
import dataclasses
import math
import threading
import time
from typing import Deque, Dict, List, Optional, Set, Tuple

from repro.runtime import telemetry
from repro.serve import api, tracing
from repro.serve.api import (FrontendStats, JobFailedError, JobHandle,
                             JobRequest, QueueFull)
from repro.serve.scheduler import PlacementScheduler

__all__ = ["PlacementFrontend"]

# same registry instrument the scheduler records into, under its own
# layer label (frontend latency = async submit -> terminal, the
# end-to-end number a client actually experiences)
_M_LATENCY = telemetry.registry().histogram(
    "repro_job_latency_ms", "Submit -> terminal wall ms, per layer",
    buckets=telemetry.DEFAULT_LATENCY_BUCKETS_MS)


def _extrapolate_eta(gens: int, budget: int, elapsed: float,
                     metric: Optional[float] = None) -> Optional[float]:
    """Remaining-wallclock estimate from a job's own generation
    throughput, or None whenever extrapolation would be garbage:

      * no generations served yet (`gens <= 0`) -- nothing to extrapolate,
      * elapsed time ~0 (first boundary landing within clock resolution)
        -- per-gen rate would divide by ~zero and explode,
      * the metric is not finite yet (no evaluated champion, so the job
        has not measurably progressed) -- an ETA would suggest progress
        that has not happened.

    Never negative: a job past its (quantized-up) budget reads 0.0.
    """
    if gens <= 0 or elapsed <= 1e-6:
        return None
    if metric is not None and not math.isfinite(metric):
        return None
    return max(elapsed / gens * (budget - gens), 0.0)


class PlacementFrontend:
    """Async admission layer owning a `PlacementScheduler` stepping thread.

    `max_queue` bounds outstanding (non-terminal) jobs: `submit()` awaits
    a free credit, `submit_nowait()` raises `QueueFull`.  All public
    coroutines/methods must be called from the event loop that ran
    `start()` (or entered the async context manager); the stepping thread
    is an implementation detail and never executes user code.
    """

    def __init__(self, scheduler: PlacementScheduler, max_queue: int = 64,
                 name: str = "placement-frontend"):
        if max_queue < 1:
            raise ValueError("max_queue must be >= 1")
        self.scheduler = scheduler
        self.max_queue = max_queue
        self._name = name
        # ---- loop-side state (event-loop thread only) -----------------
        self._loop: Optional[asyncio.AbstractEventLoop] = None
        self._credits = max_queue
        self._waiters: Deque[asyncio.Future] = collections.deque()
        self._outstanding = 0          # submitted, not yet terminal
        self._idle: Optional[asyncio.Event] = None
        self._draining = False
        self._closed = False
        # ---- shared state (command queue, guarded by _cv) -------------
        self._cv = threading.Condition()
        self._commands: Deque[Tuple[str, JobHandle]] = collections.deque()
        self._stop = False
        self._thread: Optional[threading.Thread] = None
        self.thread_error: Optional[str] = None
        # ---- stepping-thread-side state (that thread only) ------------
        self._by_jid: Dict[int, JobHandle] = {}
        self._live: Set[JobHandle] = set()
        self._first_seen: Dict[int, float] = {}
        # ---- counters (int increments; read-only elsewhere) -----------
        self.submitted = 0
        self.admitted = 0
        self.completed = 0
        self.cancelled = 0
        self.failed = 0
        self.backpressure_waits = 0
        self.queue_full_rejections = 0
        # end-to-end submit -> terminal latency (stats(); mirrors into
        # the registry histogram under layer="frontend")
        self._latency_hist = telemetry.Histogram(
            "job_latency_ms", buckets=telemetry.DEFAULT_LATENCY_BUCKETS_MS)

    # -------------------------------------------------------- lifecycle

    def start(self) -> "PlacementFrontend":
        """Capture the running loop and start the stepping thread
        (idempotent).  Must be called from within the event loop."""
        if self._closed:
            raise RuntimeError("front-end is closed")
        if self._thread is not None and self._thread.is_alive():
            return self
        self._loop = asyncio.get_running_loop()
        self._stop = False
        self._thread = threading.Thread(
            target=self._run, name=self._name, daemon=True)
        self._thread.start()
        return self

    async def __aenter__(self) -> "PlacementFrontend":
        return self.start()

    async def __aexit__(self, *exc) -> None:
        await self.aclose()

    async def drain(self) -> None:
        """Stop admitting new jobs and wait until every outstanding job
        reaches a terminal state (DONE / FAILED / CANCELLED).  Jobs are
        finished, never abandoned: nothing is lost and nothing runs
        twice.  New `submit()` calls raise after this."""
        self._draining = True
        if self._outstanding == 0:
            return
        if self._idle is None:
            self._idle = asyncio.Event()
        await self._idle.wait()

    async def aclose(self) -> None:
        """`drain()`, then join the stepping thread and close the
        scheduler (persist champion store, stop the prewarm worker).
        Idempotent."""
        if self._closed:
            return
        self._closed = True
        if self._thread is None:           # never started
            self.scheduler.close()
            return
        await self.drain()
        with self._cv:
            self._stop = True
            self._cv.notify_all()
        loop = asyncio.get_running_loop()
        await loop.run_in_executor(None, self._thread.join, 30.0)
        await loop.run_in_executor(None, self.scheduler.close)

    # -------------------------------------------------------- admission

    async def submit(self, request: JobRequest) -> JobHandle:
        """Admit one job, awaiting a free admission credit when
        `max_queue` jobs are already outstanding (backpressure: the
        caller suspends, the stepping thread keeps going).  Returns a
        `JobHandle`; `handle.jid` is assigned by the stepping thread
        moments later."""
        self._check_open()
        await self._acquire_credit()
        return self._enqueue_submit(request)

    def submit_nowait(self, request: JobRequest) -> JobHandle:
        """Non-blocking `submit()`: raises `serve.api.QueueFull` instead
        of awaiting when no admission credit is free."""
        self._check_open()
        if self._credits <= 0:
            self.queue_full_rejections += 1
            raise QueueFull(
                f"admission queue full ({self.max_queue} jobs "
                "outstanding); await submit() for backpressure")
        self._credits -= 1
        return self._enqueue_submit(request)

    def _check_open(self) -> None:
        if self._closed:
            raise RuntimeError("front-end is closed")
        if self._draining:
            raise RuntimeError("front-end is draining; no new admissions")
        if self._thread is None or not self._thread.is_alive():
            raise RuntimeError("front-end not started (use `async with` "
                               "or call start() from the event loop)")

    async def _acquire_credit(self) -> None:
        if self._credits > 0:
            self._credits -= 1
            return
        self.backpressure_waits += 1
        fut = self._loop.create_future()
        self._waiters.append(fut)
        try:
            await fut                      # woken by _release_credit
            if self._draining or self._closed:
                self._release_credit()     # drain won the race: refuse
                raise RuntimeError(
                    "front-end is draining; no new admissions")
        except asyncio.CancelledError:
            if fut.done() and not fut.cancelled():
                self._release_credit()     # granted, but caller bailed
            else:
                try:
                    self._waiters.remove(fut)
                except ValueError:
                    pass
            raise

    def _release_credit(self) -> None:
        # loop thread only: hand the credit to the oldest live waiter,
        # or bank it
        while self._waiters:
            fut = self._waiters.popleft()
            if not fut.done():
                fut.set_result(None)
                return
        self._credits += 1

    def _enqueue_submit(self, request: JobRequest) -> JobHandle:
        if tracing.enabled() and request.trace_id is None:
            # the front-end is the outermost layer: mint here so the
            # whole journey -- including queueing behind the command
            # deque -- lands on one trace
            request = request.replace(trace_id=tracing.new_trace_id())
            tracing.tracer().instant("job.submit", request.trace_id,
                                     device=request.device,
                                     budget=request.budget,
                                     layer="frontend")
        handle = JobHandle(jid=-1, request=request)
        handle._t_submit = time.monotonic()
        handle._attach_async(self._loop, asyncio.Event())
        handle._cancel_fn = lambda _jid, h=handle: self._request_cancel(h)
        self.submitted += 1
        self._outstanding += 1
        with self._cv:
            stopped = self._stop
            if not stopped:
                self._commands.append(("submit", handle))
                self._cv.notify_all()
        if stopped:                        # thread already gone: fail
            # stats (counter + latency) and trace event land BEFORE the
            # handle resolves: a caller woken by the resolve must already
            # see a consistent stats()/trace view
            self.failed += 1
            self._observe_terminal_latency(handle)
            if tracing.enabled() and request.trace_id is not None:
                tracing.tracer().instant(
                    "job.failed", request.trace_id,
                    error="front-end stepping thread stopped")
            handle._fail(JobFailedError(   # loudly instead of hanging
                "front-end stepping thread stopped"))
            self._on_terminal()
        return handle

    def _request_cancel(self, handle: JobHandle) -> bool:
        """Queue a cancel for the stepping thread (FIFO after the
        handle's own submit, so the jid is always known by the time it
        executes).  Returns True = request accepted; the outcome lands on
        `handle.status` (CANCELLED, or DONE when the job finished
        first)."""
        with self._cv:
            if self._stop:
                return False
            self._commands.append(("cancel", handle))
            self._cv.notify_all()
        return True

    # -------------------------------------------- stepping thread (own)

    def _run(self) -> None:
        try:
            while True:
                with self._cv:
                    while (not self._commands and not self.scheduler.busy
                           and not self._stop):
                        self._cv.wait()
                    if self._stop:
                        break
                    cmds = list(self._commands)
                    self._commands.clear()
                for kind, handle in cmds:
                    if kind == "submit":
                        self._do_submit(handle)
                    else:
                        self._do_cancel(handle)
                if self.scheduler.busy:
                    self._do_step()
        except BaseException as e:  # noqa: BLE001 -- a stepping crash
            # must fail loudly through every handle, never hang callers
            self.thread_error = f"{type(e).__name__}: {e}"
        finally:
            self._fail_remaining()

    def _do_submit(self, handle: JobHandle) -> None:
        try:
            jid = self.scheduler.submit_request(handle.request)
        except Exception as e:  # noqa: BLE001 -- bad request: fail the
            # handle, not the thread (co-tenant jobs keep flowing)
            self.failed += 1
            self._observe_terminal_latency(handle)
            if tracing.enabled() and handle.request.trace_id is not None:
                # the scheduler raised before emitting anything for this
                # trace; the terminal event is ours to write
                tracing.tracer().instant(
                    "job.failed", handle.request.trace_id,
                    error=f"{type(e).__name__}: {e}")
            handle._fail(e)                # resolve last: see _do_step
            self._notify_terminal(handle)
            return
        handle.jid = jid
        self._by_jid[jid] = handle
        self._live.add(handle)
        self.admitted += 1

    def _do_cancel(self, handle: JobHandle) -> None:
        if handle not in self._live:
            return                         # already terminal (or failed)
        if self.scheduler.cancel(handle.jid):
            # the scheduler (or its pool) emitted the job.cancelled event
            self.cancelled += 1
            self._observe_terminal_latency(handle)
            handle._cancelled()            # resolve last: see _do_step
            self._forget(handle)
            self._notify_terminal(handle)
        # else: finished in the same breath; resolves via _do_step

    def _do_step(self) -> None:
        for job in self.scheduler.step():
            handle = self._by_jid.get(job.jid)
            if handle is None:
                continue                   # not ours (direct submitter)
            # counters AND the latency observation first, then resolve: a
            # caller woken by the resolve must already see consistent
            # stats() -- including the histogram.  Terminal trace events
            # (harvested / cache_hit / failed) were emitted by the layer
            # that decided the outcome -- the pool or the scheduler.
            self._observe_terminal_latency(handle)
            if job.status is api.JobStatus.DONE:
                self.completed += 1
                handle._resolve(job.result)
            else:                          # surfaced as failed
                self.failed += 1
                handle._fail(JobFailedError(
                    job.error or f"job {job.jid} failed"))
            self._forget(handle)
            self._notify_terminal(handle)
        now = time.monotonic()
        for u in self.scheduler.progress():
            handle = self._by_jid.get(u.jid)
            if handle is None:
                continue
            handle._mark_running()
            t0 = self._first_seen.setdefault(u.jid, now)
            eta = _extrapolate_eta(u.gens, u.budget, now - t0, u.metric)
            handle._push_progress(dataclasses.replace(u, eta_s=eta))

    def _forget(self, handle: JobHandle) -> None:
        self._live.discard(handle)
        self._by_jid.pop(handle.jid, None)
        self._first_seen.pop(handle.jid, None)

    def _notify_terminal(self, handle: JobHandle) -> None:
        """Bounce credit release / drain bookkeeping onto the loop."""
        try:
            self._loop.call_soon_threadsafe(self._on_terminal)
        except RuntimeError:
            pass                           # loop already closed

    def _on_terminal(self) -> None:
        # loop thread: one call per handle that reached a terminal state
        self._outstanding -= 1
        self._release_credit()
        if self._outstanding <= 0 and self._idle is not None:
            self._idle.set()

    def _fail_remaining(self) -> None:
        """Thread exit with work still attached (crash, or stop without
        drain): fail every live handle and every unprocessed command so
        no caller waits forever."""
        note = self.thread_error or "front-end stepping thread stopped"
        with self._cv:
            leftovers = [h for _, h in self._commands]
            self._commands.clear()
        for handle in list(self._live) + leftovers:
            if not handle._done.is_set():
                self.failed += 1
                self._observe_terminal_latency(handle)
                if (tracing.enabled()
                        and handle.request.trace_id is not None):
                    # the scheduler will never step again, so no other
                    # layer can write this job's terminal event
                    tracing.tracer().instant(
                        "job.failed", handle.request.trace_id, error=note)
                handle._fail(JobFailedError(note))   # resolve last
                self._notify_terminal(handle)
        self._live.clear()
        self._by_jid.clear()

    def _observe_terminal_latency(self, handle: JobHandle) -> None:
        """Record async submit -> terminal latency exactly once per
        handle (`_t_submit` is zeroed after observing)."""
        t0 = getattr(handle, "_t_submit", 0.0)
        if not t0:
            return
        handle._t_submit = 0.0
        ms = (time.monotonic() - t0) * 1e3
        self._latency_hist.observe(ms)
        _M_LATENCY.observe(ms, layer="frontend")

    # ------------------------------------------------------------ stats

    def stats(self) -> FrontendStats:
        return api.stats_payload(
            max_queue=self.max_queue,
            submitted=self.submitted,
            admitted=self.admitted,
            completed=self.completed,
            cancelled=self.cancelled,
            failed=self.failed,
            backpressure_waits=self.backpressure_waits,
            queue_full_rejections=self.queue_full_rejections,
            draining=self._draining,
            fleet=self.scheduler.stats(),
            # --- appended under schema_version 2 (observability) ---
            job_latency_ms_hist=self._latency_hist.to_dict(),
            tracing_enabled=tracing.enabled(),
        )
