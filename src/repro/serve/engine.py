"""Batched serving engine: slot-based continuous batching over fixed caches.

A fixed pool of `n_slots` cache rows (KV for attention layers, SSM/conv
state for mamba/rwkv) is shared by all in-flight requests:

  submit()  -> pick a free slot, prefill the prompt into it
  step()    -> one batched decode for every active slot (single jitted call)
  finished  -> slot freed (eos or per-request max_new), results returned

Decode shapes stay static (whole pool decodes each step; inactive slots are
masked) -- the standard TPU-friendly serving discipline: no recompile as
requests come and go.  The dry-run's `serve_step` is exactly `self._decode`.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as T
from repro.models.transformer import ArchConfig


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray
    max_new: int
    out: List[int] = dataclasses.field(default_factory=list)
    slot: int = -1
    done: bool = False


class Engine:
    def __init__(self, cfg: ArchConfig, params, n_slots: int, max_len: int,
                 eos_id: int = 1, temperature: float = 0.0, seed: int = 0):
        self.cfg, self.params = cfg, params
        self.n_slots, self.max_len = n_slots, max_len
        self.eos = eos_id
        self.temperature = temperature
        self.key = jax.random.PRNGKey(seed)
        self.caches = T.init_caches(cfg, n_slots, max_len,
                                    jax.tree.leaves(params)[0].dtype)
        self.cache_len = jnp.zeros((n_slots,), jnp.int32)
        self.active = np.zeros(n_slots, bool)
        self.slot_req: List[Optional[Request]] = [None] * n_slots
        self.next_rid = 0

        self._decode = jax.jit(
            lambda p, tok, caches, clen: T.decode_step(
                p, cfg, tok, caches, clen))
        self.pending_tok = np.zeros(n_slots, np.int32)

    # ------------------------------------------------------------ admit

    def submit(self, prompt: np.ndarray, max_new: int = 32) -> Optional[int]:
        free = np.where(~self.active)[0]
        if len(free) == 0:
            return None
        slot = int(free[0])
        req = Request(self.next_rid, np.asarray(prompt, np.int32), max_new,
                      slot=slot)
        self.next_rid += 1
        self._prefill_into(req)
        self.slot_req[slot] = req
        self.active[slot] = True
        return req.rid

    def _prefill_into(self, req: Request) -> None:
        """Prefill one prompt and write its cache rows into the pool slot."""
        toks = jnp.asarray(req.prompt)[None, :]
        logits, caches_1, clen_1 = T.prefill(self.params, self.cfg, toks,
                                             self.max_len)
        slot = req.slot
        # splice the single-row caches into the pool at `slot`
        def splice(pool, one):
            return pool.at[:, slot].set(one[:, 0])
        self.caches = [jax.tree.map(splice, cp, c1)
                       for cp, c1 in zip(self.caches, caches_1)]
        self.cache_len = self.cache_len.at[slot].set(clen_1[0])
        self.pending_tok[slot] = int(jnp.argmax(logits[0]))
        req.out.append(int(self.pending_tok[slot]))

    # ------------------------------------------------------------ decode

    def step(self) -> List[Request]:
        """One batched decode across the pool; returns newly finished."""
        if not self.active.any():
            return []
        tok = jnp.asarray(self.pending_tok)
        logits, self.caches = self._decode(self.params, tok, self.caches,
                                           self.cache_len)
        self.cache_len = jnp.where(jnp.asarray(self.active),
                                   self.cache_len + 1, self.cache_len)
        if self.temperature > 0:
            self.key, k = jax.random.split(self.key)
            nxt = jax.random.categorical(k, logits / self.temperature, -1)
        else:
            nxt = jnp.argmax(logits, -1)
        nxt = np.asarray(nxt, np.int32)
        finished = []
        for slot in np.where(self.active)[0]:
            req = self.slot_req[slot]
            req.out.append(int(nxt[slot]))
            self.pending_tok[slot] = nxt[slot]
            hit_eos = nxt[slot] == self.eos
            full = int(self.cache_len[slot]) + 1 >= self.max_len
            if hit_eos or len(req.out) >= req.max_new or full:
                req.done = True
                finished.append(req)
                self.active[slot] = False
                self.slot_req[slot] = None
                self.cache_len = self.cache_len.at[slot].set(0)
        return finished

    def generate(self, prompts: List[np.ndarray], max_new: int = 32
                 ) -> Dict[int, List[int]]:
        """Convenience batch API with rolling admission."""
        queue = list(prompts)
        results: Dict[int, List[int]] = {}
        rid_of: Dict[int, int] = {}
        submitted = 0
        while queue or self.active.any():
            while queue:
                rid = self.submit(queue[0], max_new)
                if rid is None:
                    break
                rid_of[rid] = submitted
                submitted += 1
                queue.pop(0)
            for req in self.step():
                results[rid_of[req.rid]] = req.out
        return results
