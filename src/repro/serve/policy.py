"""Pluggable pool-stepping policies for the placement scheduler.

`PlacementScheduler.step()` advances exactly ONE pool's batched step per
call; *which* pool is a scheduling decision, extracted here behind the
`SteppingPolicy` protocol so traffic classes beyond FIFO fairness can be
served without touching the slot machinery:

  * `round_robin` -- the PR 2 default: pools take turns; the rotation
    pointer advances past the stepped pool (and past skipped empty pools)
    so no pool can starve behind a perpetually busy neighbour,
  * `priority`    -- highest-priority work first: a pool's urgency is the
    max `priority` over its inflight + pending jobs; ties rotate
    round-robin so equal-priority pools still share the accelerator,
  * `deadline`    -- earliest-deadline-first: a pool's urgency is the
    min `deadline` over inflight + pending jobs (absent deadlines sort
    last); ties rotate.

Policies only ever choose among pools with active slots, see a read-only
`PoolView` snapshot, and are consulted once per `step()` -- they cannot
change job results (per-job trajectories are pure functions of the job
spec; see `serve.placement_service`), only completion *order* and
latency.  `get_policy` resolves a name or passes an instance through.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, List, Optional, Protocol, Sequence


@dataclasses.dataclass
class PoolView:
    """Read-only pool snapshot handed to a policy, one per known pool.

    `jobs` covers inflight + pending fleet jobs (each with `priority` /
    `deadline` attributes); `steppable` is whether stepping this pool now
    would advance any active slot.
    """

    key: Any
    index: int                 # stable position in the scheduler's rotation
    steppable: bool
    queue_depth: int
    jobs: List[Any]


class SteppingPolicy(Protocol):
    """Chooses which pool's batched step runs next."""

    name: str

    def select(self, views: Sequence[PoolView]) -> Optional[int]:
        """Index (into `views`) of the pool to step, or None if no pool is
        steppable.  Called once per scheduler step; may keep state (e.g. a
        rotation pointer)."""
        ...


class RoundRobinPolicy:
    """Fair rotation: each call starts scanning one past the last pool it
    stepped, so a busy pool cannot shadow the pools after it."""

    name = "round_robin"

    def __init__(self) -> None:
        self._next = 0

    def select(self, views: Sequence[PoolView]) -> Optional[int]:
        n = len(views)
        if n == 0:
            return None
        start = self._next % n
        for off in range(n):
            i = (start + off) % n
            if views[i].steppable:
                self._next = (i + 1) % n
                return i
        return None


class _UrgencyPolicy:
    """Shared shape of priority/deadline: score steppable pools, pick the
    best, rotate among ties so equal-urgency pools share the device."""

    def __init__(self) -> None:
        self._tick = 0
        self._last_stepped: dict = {}

    def _score(self, view: PoolView) -> float:
        raise NotImplementedError

    def select(self, views: Sequence[PoolView]) -> Optional[int]:
        best_i, best_rank = None, None
        for i, v in enumerate(views):
            if not v.steppable:
                continue
            # least-recently-stepped breaks score ties fairly
            rank = (self._score(v), self._last_stepped.get(v.key, -1))
            if best_rank is None or rank < best_rank:
                best_i, best_rank = i, rank
        if best_i is not None:
            self._tick += 1
            self._last_stepped[views[best_i].key] = self._tick
        return best_i


class PriorityPolicy(_UrgencyPolicy):
    """Weighted service: the pool holding the highest-priority job steps
    first (higher `priority` = more urgent; default 0.0)."""

    name = "priority"

    def _score(self, view: PoolView) -> float:
        best = max((float(getattr(j, "priority", 0.0) or 0.0)
                    for j in view.jobs), default=0.0)
        return -best                     # min-rank = highest priority


class DeadlinePolicy(_UrgencyPolicy):
    """Earliest-deadline-first over pending + inflight jobs; jobs without a
    deadline sort after every dated one."""

    name = "deadline"

    def _score(self, view: PoolView) -> float:
        return min((float(j.deadline) for j in view.jobs
                    if getattr(j, "deadline", None) is not None),
                   default=math.inf)


_POLICIES = {
    "round_robin": RoundRobinPolicy,
    "priority": PriorityPolicy,
    "deadline": DeadlinePolicy,
}


def get_policy(policy) -> SteppingPolicy:
    """Resolve a policy name to a fresh instance; instances pass through."""
    if isinstance(policy, str):
        try:
            return _POLICIES[policy]()
        except KeyError:
            raise KeyError(f"unknown stepping policy {policy!r}; "
                           f"have {sorted(_POLICIES)}") from None
    if not callable(getattr(policy, "select", None)):
        raise TypeError(f"policy must be a name or expose select(); "
                        f"got {type(policy)}")
    return policy
