"""Placement-as-a-service: slot-based continuous batching of placement jobs.

Mirrors the serving discipline of `serve.engine.Engine` (fixed KV-cache
slot pool, masked batched decode) for evolutionary placement: a fixed pool
of `n_slots` *job slots* shares one compiled step program for a single
device/problem.

  submit()  -> pick a free slot, initialise the job's algorithm state into
               it (its own seed + float hyperparameters; one jitted init)
  step()    -> ONE batched jitted call advances every slot by
               `gens_per_step` generations (vmap over the slot axis;
               per-slot hyperparameters ride as traced f32 operands)
  finished  -> jobs whose generation budget is exhausted -- or whose
               combined metric hit their `target` -- are harvested (best
               genotype + objectives), the slot is freed

Jobs are reproducible: every step key derives from the *job's* seed and
its own generation counter (never a shared service stream), so a job's
result is a pure function of (config, seed, budget, gens_per_step) --
independent of co-tenant jobs and admission timing.

Shapes are static: jobs come and go by overwriting slot *contents* (state
arrays, hyperparameter rows, mask entries), never shapes, so `step()` never
recompiles -- the TPU-friendly serving discipline, now for placement
traffic.  Vacant slots keep evolving whatever state they hold; their work
is masked out of accounting and their results are never read.

Static config fields (pop_size, perm_swaps, reduced, fused, ...) are
fixed per pool at construction: they are baked into the compiled step.
Jobs whose config disagrees on those belong in a different pool --
`serve.scheduler.PlacementScheduler` routes mixed traffic across pools.
`fused=True` configs evaluate the pool's whole stacked (slots x islands x
pop) batch through the fused Pallas pipeline (`kernels.fused_eval`): the
slot/island vmaps stack batch axes onto ONE kernel launch instead of
materialising per-net endpoint and per-unit coordinate tensors per slot.

Warm starts: `submit(init_state=...)` seeds a job from a genotype (e.g.
`core.transfer.migrate`'s projection of a sibling-device champion) via a
per-pool jitted warm-init program (`core.warmstart`) -- the transfer
serving path of paper SS IV-D.

Islands: `PlacementService(..., islands=IslandConfig(P, migrate_every))`
makes every slot hold P island sub-populations (`core.islands`) instead of
one: slot states grow a leading island axis, the batched step vmaps the
islands round (P independent `step_impl`s + ring champion migration at
global-generation boundaries) over the slot axis, and harvest returns the
best genotype across a slot's islands.  The island config is static --
part of the pool's compiled-program signature, like pop_size -- so an
islands pool keeps the exact serving discipline above (one step compile,
jobs come and go by content).  Warm seeds land on island 0 and diffuse to
the other islands via migration.
"""
from __future__ import annotations

import collections
import contextlib
import dataclasses
import functools
import itertools
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hyper, portfolio, warmstart
from repro.core import islands as islands_mod
from repro.core import objectives as O
from repro.core.islands import IslandConfig
from repro.fpga.netlist import Problem
from repro.runtime import compile_cache, telemetry
from repro.serve import api, tracing
from repro.serve.api import JobRequest, ServiceStats

# registry-global instruments (recording is host-side arithmetic, cheap
# next to a jitted step; exporters are what the config flags gate)
_REG = telemetry.registry()
_M_STEPS = _REG.counter(
    "repro_service_steps_total", "Batched service step() calls")
_M_GENS = _REG.counter(
    "repro_useful_gens_total", "Active-slot generations actually served")
_M_HARVESTED = _REG.counter(
    "repro_jobs_harvested_total", "Jobs harvested at budget/target")
_M_CANCELLED = _REG.counter(
    "repro_jobs_cancelled_total", "In-flight slots freed early by cancel()")
_M_STEP_MS = _REG.histogram(
    "repro_service_step_ms", "Wall ms per batched service step",
    buckets=telemetry.DEFAULT_LATENCY_BUCKETS_MS)
_M_BEST = _REG.gauge(
    "repro_pool_best_metric",
    "Best combined metric across a pool's active slots (live convergence)")

# per-job convergence ring depth: (gens, metric) pairs at step boundaries
CONVERGENCE_RING = 256
# tail length surfaced through ProgressUpdate / stats() (the full ring
# stays on the job and on JobHandle.trace())
CONVERGENCE_TAIL = 8

_POOL_COUNTER = itertools.count(1)


def make_job_specs(n: int, pop_size: int, budget: int, seed: int = 0,
                   eta_range=(5.0, 25.0), mut_range=(0.05, 0.3),
                   fused: bool = False) -> List[Dict]:
    """Synthetic placement workload: n NSGA-II jobs with jittered float
    hyperparameters (shared by the CLI demo, the example, and the bench,
    so they all exercise the same traffic shape).

    `fused=True` routes every job's evaluation through the fused Pallas
    pipeline (`kernels.fused_eval`); it is a static config field, so fused
    and unfused jobs belong to different pools."""
    from repro.core import nsga2
    rng = np.random.default_rng(seed)
    return [dict(seed=seed * 10_000 + i, budget=budget,
                 cfg=nsga2.NSGA2Config(
                     pop_size=pop_size,
                     sbx_eta=float(rng.uniform(*eta_range)),
                     real_mut_prob=float(rng.uniform(*mut_range)),
                     fused=fused))
            for i in range(n)]


@dataclasses.dataclass
class PlacementJob:
    jid: int
    cfg: Any                       # full config (floats may differ per job)
    seed: int
    budget: int                    # generation budget
    target: Optional[float]        # finish early if combined metric <= this
    slot: int = -1
    gens: int = 0                  # generations run so far
    warm: bool = False             # seeded via submit(init_state=...)
    done: bool = False
    cancelled: bool = False        # slot freed early by cancel()
    best_objs: Optional[np.ndarray] = None   # [2] = (wl^2, max bbox)
    metric: float = float("inf")             # combined metric of best_objs
    genotype: Any = None                     # best full genotype at harvest
    trace_id: Optional[str] = None           # observability only
    # per-step convergence ring: (gens, metric) recorded at every step
    # boundary the job was alive for -- the paper's Fig. 7 curve as a
    # live signal (bounded; never read by jitted code)
    history: Any = dataclasses.field(
        default_factory=lambda: collections.deque(maxlen=CONVERGENCE_RING))


class PlacementService:
    """Continuous-batching placement engine for one `Problem`."""

    def __init__(self, problem: Problem, base_cfg, algo: str = "nsga2",
                 n_slots: int = 8, gens_per_step: int = 4, seed: int = 0,
                 islands: Optional[IslandConfig] = None,
                 label: Optional[str] = None):
        self.problem, self.algo = problem, algo
        self.n_slots, self.gens_per_step = n_slots, gens_per_step
        # observability-only pool name (metric label / span attr); the
        # scheduler passes its pool-signature label, standalone pools get
        # a process-unique default
        self.label = label or f"pool{next(_POOL_COUNTER)}/{algo}"
        if tracing.enabled():
            tracing.tracer().begin("pool.build", pool=self.label,
                                   n_slots=n_slots, algo=algo)
        # island topology is static pool identity, exactly like pop_size:
        # P > 1 swaps the slot programs for their island-stacked mirrors
        # (`core.islands`); P == 1 keeps the original single-population
        # programs bit for bit
        self.islands = islands or IslandConfig()
        self.static_key, base_traced = hyper.split_config(base_cfg)
        self.base_cfg = base_cfg
        self._base_traced = dict(base_traced)   # grow() fills new slots
        self.size_history: List[int] = [n_slots]  # every slot count compiled
        # host mirror of the per-slot traced hyperparameters
        self.traced = {k: np.full(n_slots, v, np.float32)
                       for k, v in base_traced.items()}
        self.active = np.zeros(n_slots, bool)
        self.slot_job: List[Optional[PlacementJob]] = [None] * n_slots
        # per-slot (seed, generation counter): step keys derive from the
        # *job's* seed, never a shared stream, so a job's trajectory is a
        # pure function of (seed, budget, gens_per_step) -- identical on an
        # empty or a fully-loaded pool, reproducible across submissions
        self.slot_seed = np.zeros(n_slots, np.uint32)
        self.slot_gens = np.zeros(n_slots, np.int32)
        self.next_jid = 0
        self.key = jax.random.PRNGKey(seed)
        self.total_steps = 0
        self.useful_gens = 0       # active-slot generations actually served
        self.jobs_cancelled = 0    # slots freed early via cancel()
        # compile observability: the process meter separates *blocking*
        # compiles (on the thread calling submit/step/grow -- the stepping
        # loop's latency) from background prewarm compiles
        # (`prewarm_size`, typically run by `serve.prewarm.Prewarmer`)
        self._meter = compile_cache.meter().install()
        self.blocking_compiles = 0
        self.blocking_compile_secs = 0.0
        self.prewarm_compiles = 0
        self.prewarm_compile_secs = 0.0
        self._prewarmed_sizes: set = set()
        self._created_at = time.perf_counter()
        self._first_gen_ms: Optional[float] = None

        # per-pool jitted programs; problem/algo/static config (and the
        # island config) are closure constants, so each compiles exactly
        # once for the pool's shapes.  Step keys derive inside the program
        # from (slot seed, slot gens), so the host ships two small int
        # arrays, not key material.
        icfg = self.islands
        if icfg.active:
            self._init_fn = jax.jit(functools.partial(
                islands_mod.member_init, problem, algo, self.static_key,
                icfg))
            self._fill_fn = functools.partial(
                islands_mod._vinit, problem, algo, self.static_key, icfg)
        else:
            self._init_fn = jax.jit(functools.partial(
                portfolio.member_init, problem, algo, self.static_key))
            self._fill_fn = functools.partial(
                portfolio._vinit, problem, algo, self.static_key)
        # warm-start init: the seed block rides as a traced operand at the
        # pool's canonical shape (`warmstart.seed_rows`), so transfer-seeded
        # jobs share ONE compiled warm-init regardless of their hyperparams.
        # Islands pools seed island 0 and let migration spread it.
        self._seed_rows = warmstart.seed_rows(algo, self.static_key)
        if icfg.active:
            self._warm_init_fn = jax.jit(functools.partial(
                islands_mod.member_warm_init, problem, algo,
                self.static_key, icfg))
        else:
            self._warm_init_fn = jax.jit(functools.partial(
                warmstart.member_warm_init, problem, algo, self.static_key))

        def _step(traced, states, seeds, gens):
            def one(tr, st, s, g):
                key = jax.random.fold_in(jax.random.PRNGKey(s), g)
                if icfg.active:
                    # g doubles as the migration phase: boundaries are
                    # counted in global generations, invariant to
                    # gens_per_step chunking and admission timing
                    return islands_mod.member_round(
                        problem, algo, self.static_key, icfg,
                        gens_per_step, tr, st, key, g)
                return portfolio.member_round(
                    problem, algo, self.static_key, gens_per_step,
                    tr, st, key)
            return jax.vmap(one)(traced, states, seeds, gens)

        self._step_fn = jax.jit(_step)

        # fill the pool with throwaway states so step() shapes exist from
        # the first call (vacant slots evolve garbage; it is never read)
        # per-pool step-latency histogram (the registry-global one
        # aggregates across pools; this instance feeds stats())
        self._step_hist = telemetry.Histogram(
            "step_ms", buckets=telemetry.DEFAULT_LATENCY_BUCKETS_MS)

        k_fill = jax.random.fold_in(self.key, 0x5eed)
        with self._blocking():
            self.states = self._fill_fn(self._traced_dev(),
                                        jax.random.split(k_fill, n_slots))
        if tracing.enabled():
            tracing.tracer().end("pool.build", pool=self.label,
                                 n_slots=n_slots, algo=algo)

    @contextlib.contextmanager
    def _blocking(self):
        """Attribute compiles on the calling thread to this pool's
        blocking counters (the stepping loop's compile latency)."""
        with self._meter.measure() as m:
            yield
        self.blocking_compiles += m.compiles
        self.blocking_compile_secs += m.secs

    # ------------------------------------------------------------- admit

    def submit(self, cfg=None, seed: Optional[int] = None, budget: int = 64,
               target: Optional[float] = None, init_state=None,
               jitter: float = 0.15,
               sigma_shrink: float = 0.25) -> Optional[int]:
        """Admit one job; returns its jid, or None if the pool is full.

        The canonical form is `submit(request)` with a
        `serve.api.JobRequest` as the only argument; the kwarg form
        survives as a deprecated shim that builds the same request
        (results are bitwise identical -- the shim only repackages
        arguments).

        Budgets are quantized UP to the pool's `gens_per_step` granularity
        (the batched step advances whole steps only); `job.budget` records
        the quantized value, which the job then runs exactly.

        `init_state` warm-starts the job from a seed genotype (or stacked
        population / reduced perm tuple) on *this* pool's problem --
        typically `transfer.migrate(base, target, champion)`.  The seed is
        padded/truncated to the pool's static shape on the host and turned
        into an algorithm state by one per-pool jitted warm-init program
        (`core.warmstart`): NSGA-II/GA populations keep the seed at row 0
        and fill the rest with `jitter`-scaled copies, CMA-ES starts its
        mean at the seed with `sigma0 * sigma_shrink`, SA starts its chain
        there.  Warm jobs stay reproducible: the result is a pure function
        of (config, seed, budget, init_state, jitter, sigma_shrink).
        """
        if isinstance(cfg, JobRequest):
            request = cfg
        else:
            request = api.deprecated_kwargs_request(
                "PlacementService", cfg=cfg, seed=seed, budget=budget,
                target=target, init_state=init_state, jitter=jitter,
                sigma_shrink=sigma_shrink, algo=self.algo)
        return self.submit_request(request)

    def submit_request(self, request: JobRequest) -> Optional[int]:
        """`submit()` on the unified request type (no shim, no warning):
        admit one job described by a `serve.api.JobRequest`; returns its
        jid, or None when the pool is full.

        Routing fields are validated, never silently re-routed: a request
        whose `algo` or `islands` disagrees with this pool raises (the
        scheduler is the layer that routes mixed traffic)."""
        if request.algo is not None and request.algo != self.algo:
            raise ValueError(
                f"request.algo={request.algo!r} does not match this "
                f"pool's algo={self.algo!r}; route via PlacementScheduler")
        if (request.islands is not None
                and request.islands != self.islands):
            raise ValueError(
                f"request.islands={request.islands} does not match this "
                f"pool's islands={self.islands}; route via "
                "PlacementScheduler")
        if (request.gens_per_step is not None
                and request.gens_per_step != self.gens_per_step):
            raise ValueError(
                f"request.gens_per_step={request.gens_per_step} does not "
                f"match this pool's gens_per_step={self.gens_per_step}")
        cfg = request.resolved_cfg(self.base_cfg)
        seed, target = request.seed, request.target
        init_state = request.init_state
        jitter, sigma_shrink = request.jitter, request.sigma_shrink
        budget = -(-request.budget // self.gens_per_step) \
            * self.gens_per_step
        static_key, traced = hyper.split_config(cfg)
        if static_key != self.static_key:
            raise ValueError(
                "job config disagrees with the pool's static fields "
                f"({static_key[1]} vs {self.static_key[1]}); "
                "open a separate pool for it")
        free = np.where(~self.active)[0]
        if len(free) == 0:
            return None
        slot = int(free[0])
        seed = self.next_jid if seed is None else seed
        trace_id = request.trace_id
        if tracing.enabled() and trace_id is None:
            # direct pool submission (no scheduler/front-end above us):
            # this layer is the outermost, so it mints and announces
            trace_id = tracing.new_trace_id()
            tracing.tracer().instant("job.submit", trace_id,
                                     algo=self.algo, budget=budget)
        job = PlacementJob(self.next_jid, cfg, seed, budget, target,
                           slot=slot, warm=init_state is not None,
                           trace_id=trace_id)
        self.next_jid += 1
        if tracing.enabled():
            tracing.tracer().instant("job.admitted", trace_id,
                                     slot=slot, pool=self.label,
                                     warm=job.warm)
        traced_dev = {k: jnp.float32(v) for k, v in traced.items()}
        with self._blocking():
            if init_state is None:
                state1 = self._init_fn(traced_dev, jax.random.PRNGKey(seed))
            else:
                pop, fresh = warmstart.canonicalize(
                    self.problem, init_state, self._seed_rows)
                state1 = self._warm_init_fn(
                    traced_dev, jax.tree.map(jnp.asarray, pop),
                    jnp.asarray(fresh), jnp.float32(jitter),
                    jnp.float32(sigma_shrink), jax.random.PRNGKey(seed))
        # splice the single job state into the pool at `slot`
        self.states = jax.tree.map(
            lambda pool, one: pool.at[slot].set(one), self.states, state1)
        for k, v in traced.items():
            self.traced[k][slot] = v
        self._traced_cache = None          # hyperparameter row changed
        self.slot_seed[slot] = np.uint32(seed)
        self.slot_gens[slot] = 0
        self.active[slot] = True
        self.slot_job[slot] = job
        return job.jid

    # ------------------------------------------------------------- cancel

    def cancel(self, jid: int) -> bool:
        """Cancel an in-flight job: its slot is freed immediately (the
        vacant slot keeps evolving garbage that is never read, exactly
        like a harvested one) and is reusable by the next `submit()`.

        Call between `step()`s -- the step boundary.  The async front-end
        (`serve.frontend`) guarantees this by executing cancels on the
        stepping thread; direct callers own the discipline themselves.
        Returns False when the jid is not currently in flight (already
        harvested, cancelled, or never admitted).  Cancellation cannot
        perturb co-tenant jobs: their trajectories depend only on their
        own (seed, gens), never on slot occupancy."""
        for slot in np.where(self.active)[0]:
            job = self.slot_job[slot]
            if job is not None and job.jid == jid:
                job.cancelled = True
                self.active[slot] = False
                self.slot_job[slot] = None
                self.jobs_cancelled += 1
                _M_CANCELLED.inc()
                if tracing.enabled():
                    tracing.tracer().instant(
                        "job.cancelled", job.trace_id,
                        slot=int(slot), gens=job.gens)
                return True
        return False

    def job(self, jid: int) -> Optional[PlacementJob]:
        """The in-flight job with this jid (None once harvested/cancelled
        -- finished jobs are returned by `step()`, not looked up here)."""
        for slot in np.where(self.active)[0]:
            job = self.slot_job[slot]
            if job is not None and job.jid == jid:
                return job
        return None

    def inflight(self) -> List[PlacementJob]:
        """Snapshot of the jobs currently occupying slots (progress
        streaming reads `gens`/`metric`/`best_objs` off these between
        steps)."""
        return [self.slot_job[slot] for slot in np.where(self.active)[0]
                if self.slot_job[slot] is not None]

    # -------------------------------------------------------------- grow

    def grow(self, n_slots: int) -> None:
        """Rebuild the pool at a larger static slot count, carrying every
        live slot's state over on the host.

        The slot axis is a static shape, so the batched step compiles once
        per *size* -- which is why callers (the scheduler's autoscaler)
        restrict sizes to a small geometric ladder rather than growing by
        one.  In-flight jobs are untouched: their states, hyperparameter
        rows, seeds and generation counters keep their slot index, and a
        job's trajectory depends only on (seed, gens) -- never the batch
        width -- so results stay identical to a never-grown pool.  New
        slots arrive vacant, filled with throwaway states (same discipline
        as construction).
        """
        if n_slots <= self.n_slots:
            raise ValueError(
                f"grow() only grows: {n_slots} <= current {self.n_slots}")
        if tracing.enabled():
            tracing.tracer().begin("pool.grow", pool=self.label,
                                   from_slots=self.n_slots,
                                   to_slots=n_slots)
        extra = n_slots - self.n_slots
        k_fill = jax.random.fold_in(self.key, 0x5eed + n_slots)
        fill_traced = {k: jnp.full((extra,), v, jnp.float32)
                       for k, v in self._base_traced.items()}
        with self._blocking():
            fill = self._fill_fn(fill_traced,
                                 jax.random.split(k_fill, extra))
            self.states = jax.tree.map(
                lambda a, b: jnp.concatenate([a, b], axis=0),
                self.states, fill)
        self.traced = {
            k: np.concatenate(
                [v, np.full(extra, self._base_traced[k], np.float32)])
            for k, v in self.traced.items()}
        self._traced_cache = None
        self.active = np.concatenate([self.active, np.zeros(extra, bool)])
        self.slot_job.extend([None] * extra)
        self.slot_seed = np.concatenate(
            [self.slot_seed, np.zeros(extra, np.uint32)])
        self.slot_gens = np.concatenate(
            [self.slot_gens, np.zeros(extra, np.int32)])
        self.n_slots = n_slots
        self.size_history.append(n_slots)
        if tracing.enabled():
            tracing.tracer().end("pool.grow", pool=self.label,
                                 to_slots=n_slots)

    # ----------------------------------------------------------- prewarm

    def prewarm_size(self, n_slots: int) -> bool:
        """Ahead-of-time compile the programs a future `grow(n_slots)`
        needs: the fill at the extra-slot width and the batched step (and
        its combined-metric epilogue) at the full `n_slots` width.

        Runs the pool's OWN jitted callables on throwaway inputs of the
        target shapes, so the later `grow()` + `step()` hit the in-memory
        jit caches and perform zero blocking compiles -- the grow becomes
        pure host-side state surgery.  Compiles land in the prewarm
        counters, not the blocking ones; designed to run on a background
        thread (`serve.prewarm.Prewarmer`) while the pool keeps stepping
        at its current size (only array *shapes* matter here, so racing a
        concurrent step is benign).  Returns True when work was done,
        False for an already-prewarmed or non-growing size.
        """
        base, states = self.n_slots, self.states   # snapshot
        if n_slots <= base or n_slots in self._prewarmed_sizes:
            return False
        if tracing.enabled():
            tracing.tracer().begin("pool.prewarm_size", pool=self.label,
                                   n_slots=n_slots)
        extra = n_slots - base
        with self._meter.measure() as m:
            k_fill = jax.random.fold_in(self.key, 0x9ae + n_slots)
            fill_traced = {k: jnp.full((extra,), v, jnp.float32)
                           for k, v in self._base_traced.items()}
            fill = self._fill_fn(fill_traced,
                                 jax.random.split(k_fill, extra))
            probe = jax.tree.map(
                lambda a, b: jnp.concatenate([a, b], axis=0), states, fill)
            # operands built exactly as step() builds them (jnp.array
            # copies of numpy mirrors): the per-(dtype, width) host-copy
            # programs compile here too, not in the stepping loop
            traced = {k: jnp.array(np.full(n_slots, v, np.float32))
                      for k, v in self._base_traced.items()}
            _, best = self._step_fn(traced, probe,
                                    jnp.array(np.zeros(n_slots, np.uint32)),
                                    jnp.array(np.zeros(n_slots, np.int32)))
            # step()'s epilogue ops compile per slot-count too
            jax.block_until_ready(O.combined_metric(best))
        self._prewarmed_sizes.add(n_slots)
        self.prewarm_compiles += m.compiles
        self.prewarm_compile_secs += m.secs
        if tracing.enabled():
            tracing.tracer().end("pool.prewarm_size", pool=self.label,
                                 n_slots=n_slots, compiles=m.compiles)
        return True

    # -------------------------------------------------------------- step

    _traced_cache: Optional[Dict[str, jnp.ndarray]] = None

    def _traced_dev(self) -> Dict[str, jnp.ndarray]:
        """Device copy of the per-slot hyperparameters, re-uploaded only
        when submit() changed a row (the step loop reuses the cache).

        jnp.array (copy=True), NOT asarray: CPU jax may zero-copy a numpy
        buffer, and submit() mutates these mirrors in place -- an aliased
        buffer would let a later submit corrupt an in-flight step."""
        if self._traced_cache is None:
            self._traced_cache = {k: jnp.array(v)
                                  for k, v in self.traced.items()}
        return self._traced_cache

    def step(self) -> List[PlacementJob]:
        """Advance every slot `gens_per_step` generations in one jitted
        call; harvest and return newly finished jobs."""
        if not self.active.any():
            return []
        n_active = int(self.active.sum())
        traced_on = tracing.enabled()
        if traced_on:
            tracing.tracer().begin("pool.step", pool=self.label,
                                   active=n_active)
        t_step = time.perf_counter()
        # jnp.array copies: the numpy mirrors are mutated in place below
        # and by submit(), and CPU jax may otherwise alias their buffers
        # while the dispatched step is still consuming them
        with self._blocking():
            self.states, best = self._step_fn(
                self._traced_dev(), self.states,
                jnp.array(self.slot_seed), jnp.array(self.slot_gens))
        self.total_steps += 1
        self.useful_gens += int(self.active.sum()) * self.gens_per_step
        self.slot_gens += self.gens_per_step
        best = np.asarray(best)
        metric = np.asarray(O.combined_metric(best))
        if self._first_gen_ms is None:
            # first generations actually served: the pool's cold-start
            # latency (construction + first submit + first step, compiles
            # included) -- the number the compile bench/CI budget watches
            self._first_gen_ms = (time.perf_counter()
                                  - self._created_at) * 1e3
        finished = []
        best_active = float("inf")
        for slot in np.where(self.active)[0]:
            job = self.slot_job[slot]
            job.gens += self.gens_per_step
            job.best_objs = best[slot]
            job.metric = float(metric[slot])
            # live convergence: one (gens, metric) point per step boundary
            job.history.append((job.gens, job.metric))
            best_active = min(best_active, job.metric)
            hit_target = job.target is not None and job.metric <= job.target
            if job.gens >= job.budget or hit_target:
                self._harvest(slot, job)
                finished.append(job)
                self.active[slot] = False
                self.slot_job[slot] = None
                _M_HARVESTED.inc()
                if traced_on:
                    tracing.tracer().instant(
                        "job.harvested", job.trace_id, slot=int(slot),
                        gens=job.gens, metric=job.metric,
                        hit_target=hit_target)
        step_ms = (time.perf_counter() - t_step) * 1e3
        self._step_hist.observe(step_ms)
        _M_STEP_MS.observe(step_ms)
        _M_STEPS.inc()
        _M_GENS.inc(int(self.active.sum() + len(finished))
                    * self.gens_per_step)
        if best_active != float("inf"):
            _M_BEST.set(best_active, pool=self.label)
        if traced_on:
            tracing.tracer().end("pool.step", pool=self.label,
                                 harvested=len(finished))
        return finished

    def _harvest(self, slot: int, job: PlacementJob) -> None:
        state = jax.tree.map(lambda a: a[slot], self.states)
        if self.islands.active:
            g, objs = islands_mod.best_genotype(self.problem, self.algo,
                                                state, job.cfg)
        else:
            g, objs = portfolio.best_genotype(self.problem, self.algo,
                                              state, job.cfg)
        job.genotype = jax.tree.map(np.asarray, g)
        job.best_objs = np.asarray(objs)
        job.metric = float(O.combined_metric(job.best_objs))
        job.done = True

    # ------------------------------------------------------- conveniences

    @property
    def step_compiles(self) -> int:
        """Distinct compilations of the batched step: must stay 1 for a
        fixed-size pool, and at most `len(size_history)` after `grow()`
        (one compile per slot-count ladder size, never per job).

        Reads jax's private jit-cache counter; returns -1 (unknown) if a
        jax upgrade removes it, rather than breaking the service."""
        try:
            return self._step_fn._cache_size()
        except AttributeError:
            return -1

    def run_jobs(self, specs: List[Dict]) -> List[PlacementJob]:
        """Rolling admission: submit specs as slots free up, step until
        every job finishes.  Each spec is a `serve.api.JobRequest` or a
        dict of its fields (the `make_job_specs` shape)."""
        queue = [s if isinstance(s, JobRequest)
                 else JobRequest(algo=self.algo, **s) for s in specs]
        done: List[PlacementJob] = []
        while queue or self.active.any():
            while queue:
                if self.submit_request(queue[0]) is None:
                    break
                queue.pop(0)
            done.extend(self.step())
        return done

    def stats(self) -> ServiceStats:
        return api.stats_payload(
            n_slots=self.n_slots,
            gens_per_step=self.gens_per_step,
            steps=self.total_steps,
            useful_gens=self.useful_gens,
            step_compiles=self.step_compiles,
            sizes=list(self.size_history),
            n_islands=self.islands.n_islands,
            migrate_every=self.islands.migrate_every,
            jobs_cancelled=self.jobs_cancelled,
            # compile observability (process meter + this pool's split of
            # blocking vs prewarmed compiles; see runtime.compile_cache)
            blocking_compiles=self.blocking_compiles,
            blocking_compile_secs=round(self.blocking_compile_secs, 3),
            prewarm_compiles=self.prewarm_compiles,
            prewarm_compile_secs=round(self.prewarm_compile_secs, 3),
            prewarmed_sizes=sorted(self._prewarmed_sizes),
            time_to_first_gen_ms=(
                None if self._first_gen_ms is None
                else round(self._first_gen_ms, 1)),
            compiles_total=self._meter.compiles,
            recompiles_total=self._meter.recompiles,
            compile_secs_total=round(self._meter.compile_secs, 3),
            persistent_cache_dir=compile_cache.enabled_dir(),
            # --- appended under schema_version 2 (observability) ---
            step_ms_hist=self._step_hist.to_dict(),
            convergence={
                job.jid: list(job.history)[-CONVERGENCE_TAIL:]
                for job in self.inflight()},
            tracing_enabled=tracing.enabled(),
        )
