"""Champion store: per-problem-signature cache of best known placements.

The paper's transfer result (SS IV-D, Table II: 11-14x faster placement by
reusing a sibling device's champion) becomes a *serving* asset here: every
harvested job writes its champion back under the problem's content
signature (`fpga.netlist.Problem.signature`), and every new job consults
the store before burning a slot --

  * **exact hit**   -- an entry with the same signature whose metric
    already meets the job's `target` is the answer; the scheduler serves
    it in O(ms) with zero generations,
  * **warm hit**    -- otherwise the best exact-or-sibling entry
    (`Problem.sibling_key`) is projected onto the job's problem by
    `core.transfer.auto_migrate` (identity for exact, `migrate` for
    siblings) and injected as the job's `init_state`,
  * **write-back**  -- `put()` replaces an entry only when the new metric
    strictly improves it, so the store is monotone: serving traffic can
    only sharpen the cache.

Entries carry metric + objectives + provenance (device, algo, seed, gens)
and the store round-trips through JSON (`save`/`load`), so a fleet can
persist its accumulated champions across processes.  The store is pure
host-side numpy: no jitted program ever depends on it, which is what keeps
cache-disabled behaviour bitwise identical to a store-less scheduler.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
import warnings
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.core import transfer
from repro.fpga.netlist import Problem
from repro.serve import api

# genotype leaf dtypes, by tier (JSON carries nested lists; dtypes restore
# the exact arrays `PlacementService.submit(init_state=...)` expects)
_TIER_DTYPE = {"dist": np.float32, "loc": np.float32, "perm": np.int32}


@dataclasses.dataclass
class ChampionEntry:
    """Best known placement for one problem signature."""

    signature: str
    sibling_key: str
    device_name: str
    metric: float                       # combined metric (lower is better)
    best_objs: np.ndarray               # [2] = (wl^2, max bbox)
    genotype: Dict[str, Tuple[np.ndarray, ...]]
    provenance: Dict[str, Any]          # algo/seed/gens/... of the producer
    updated_unix: float = 0.0

    def to_json(self) -> Dict[str, Any]:
        return {
            "signature": self.signature,
            "sibling_key": self.sibling_key,
            "device_name": self.device_name,
            "metric": self.metric,
            "best_objs": np.asarray(self.best_objs).tolist(),
            "genotype": {tier: [np.asarray(a).tolist() for a in leaves]
                         for tier, leaves in self.genotype.items()},
            "provenance": self.provenance,
            "updated_unix": self.updated_unix,
        }

    @classmethod
    def from_json(cls, d: Dict[str, Any]) -> "ChampionEntry":
        return cls(
            signature=d["signature"],
            sibling_key=d["sibling_key"],
            device_name=d["device_name"],
            metric=float(d["metric"]),
            best_objs=np.asarray(d["best_objs"], np.float32),
            genotype={tier: tuple(np.asarray(a, _TIER_DTYPE[tier])
                                  for a in leaves)
                      for tier, leaves in d["genotype"].items()},
            provenance=dict(d["provenance"]),
            updated_unix=float(d.get("updated_unix", 0.0)),
        )


def _as_host_genotype(g) -> Dict[str, Tuple[np.ndarray, ...]]:
    return {tier: tuple(np.asarray(a, _TIER_DTYPE[tier]) for a in leaves)
            for tier, leaves in g.items()}


@dataclasses.dataclass
class PoolPrediction:
    """One row of the store's signature-traffic distribution: enough to
    rebuild the pool a future job with this signature will route to
    (`serve.prewarm` compiles it before that job arrives)."""

    signature: str
    device_name: str
    algo: str
    pop_size: Optional[int]
    count: int                          # submissions seen for the signature


class ChampionStore:
    """In-process (JSON-persistable) map: problem signature -> champion."""

    def __init__(self, path: Optional[str] = None):
        self._by_sig: Dict[str, ChampionEntry] = {}
        # signature -> {count, device_name, algo, pop_size}: the traffic
        # distribution `predicted_keys` mines for AOT pool prewarming;
        # persisted with the snapshot so predictions survive a restart
        self._traffic: Dict[str, Dict[str, Any]] = {}
        self.path = path
        self.hits_exact = 0
        self.hits_sibling = 0
        self.misses = 0
        self.puts = 0
        self.improvements = 0
        if path is not None:
            try:
                self.load(path)
            except FileNotFoundError:
                pass
            except json.JSONDecodeError as e:
                # a torn/corrupt snapshot must not brick startup: start
                # empty and leave the file for inspection (the next
                # save() rewrites it atomically)
                warnings.warn(f"champion store {path!r} is unreadable "
                              f"({e}); starting empty", stacklevel=2)

    def __len__(self) -> int:
        return len(self._by_sig)

    # ---------------------------------------------------------- write side

    def put(self, problem: Problem, genotype, metric: float, best_objs,
            provenance: Optional[Dict[str, Any]] = None) -> bool:
        """Record a harvested champion; keeps an entry only if it improves.

        Returns True when the entry was created or replaced (strictly
        better metric), False when the existing champion already beats it.
        """
        self.puts += 1
        metric = float(metric)
        cur = self._by_sig.get(problem.signature)
        if cur is not None and cur.metric <= metric:
            return False
        self._by_sig[problem.signature] = ChampionEntry(
            signature=problem.signature,
            sibling_key=problem.sibling_key,
            device_name=problem.device_name,
            metric=metric,
            best_objs=np.asarray(best_objs, np.float32).copy(),
            genotype=_as_host_genotype(genotype),
            provenance=dict(provenance or {}),
            updated_unix=time.time(),
        )
        self.improvements += 1
        return True

    # -------------------------------------------------------- traffic side

    def note_traffic(self, problem: Problem, algo: str = "nsga2",
                     pop_size: Optional[int] = None) -> None:
        """Record one submission against the problem's signature (the
        scheduler calls this on every `submit`); feeds `predicted_keys`."""
        row = self._traffic.setdefault(problem.signature, {
            "count": 0, "device_name": problem.device_name,
            "algo": algo, "pop_size": pop_size})
        row["count"] += 1
        # latest spelling wins: traffic can migrate to a new algo/pop
        row["device_name"] = problem.device_name
        row["algo"] = algo
        if pop_size is not None:
            row["pop_size"] = pop_size

    def predicted_keys(self, top_k: Optional[int] = None
                       ) -> List[PoolPrediction]:
        """The signature-traffic distribution, hottest first: the pool
        specs a prewarmer should compile ahead of the next job wave."""
        rows = sorted(self._traffic.items(),
                      key=lambda kv: (-kv[1]["count"], kv[0]))
        if top_k is not None:
            rows = rows[:top_k]
        return [PoolPrediction(signature=sig,
                               device_name=row["device_name"],
                               algo=row["algo"],
                               pop_size=row.get("pop_size"),
                               count=row["count"])
                for sig, row in rows]

    # ----------------------------------------------------------- read side

    def get(self, signature: str) -> Optional[ChampionEntry]:
        return self._by_sig.get(signature)

    def lookup(self, problem: Problem) -> Tuple[Optional[ChampionEntry], str]:
        """Best entry for a problem: ("exact" | "sibling" | "miss").

        Exact = same signature.  Sibling = best (lowest-metric) entry
        sharing the problem's `sibling_key`; its metric was measured on
        *its own* problem, so sibling metrics rank donors but never decide
        an instant serve.
        """
        entry = self._by_sig.get(problem.signature)
        if entry is not None:
            self.hits_exact += 1
            return entry, "exact"
        sibs = [e for e in self._by_sig.values()
                if e.sibling_key == problem.sibling_key]
        if sibs:
            self.hits_sibling += 1
            return min(sibs, key=lambda e: e.metric), "sibling"
        self.misses += 1
        return None, "miss"

    def seed_for(self, problem: Problem, entry: ChampionEntry,
                 problem_of=None) -> Dict[str, Tuple[np.ndarray, ...]]:
        """Project an entry's champion onto `problem` as a warm-start seed.

        Signature-routed (`transfer.auto_migrate`): an exact entry comes
        back untouched, a sibling entry is re-targeted through the
        three-tier migration.  The donor problem is resolved by
        `problem_of(device_name)` when given (the scheduler passes its own
        memoised resolver so problems are built once per process);
        standalone use falls back to an internal memo.
        """
        if entry.signature == problem.signature:
            return entry.genotype
        src = (problem_of or self._donor_problem)(entry.device_name)
        return transfer.auto_migrate(src, problem, entry.genotype)

    _donor_cache: Optional[Dict[str, Problem]] = None

    def _donor_problem(self, device_name: str) -> Problem:
        if self._donor_cache is None:
            self._donor_cache = {}
        if device_name not in self._donor_cache:
            from repro.fpga import device, netlist
            self._donor_cache[device_name] = netlist.make_problem(
                device.get_device(device_name))
        return self._donor_cache[device_name]

    # --------------------------------------------------------- persistence

    def save(self, path: Optional[str] = None) -> str:
        path = path or self.path
        if path is None:
            raise ValueError("no path: pass save(path) or construct "
                             "ChampionStore(path=...)")
        doc = {"champion_store": 1,
               "entries": [e.to_json() for e in self._by_sig.values()],
               # append-only doc key (old readers ignore it; old files
               # load fine without it): traffic survives restarts so a
               # fresh process can prewarm its predicted pools
               "traffic": self._traffic}
        # write-then-rename: a crash mid-dump must never tear an existing
        # snapshot (readers see the old file or the new one, never half)
        tmp = f"{path}.tmp.{os.getpid()}"
        with open(tmp, "w") as f:
            json.dump(doc, f)
            f.write("\n")
        os.replace(tmp, path)
        return path

    def load(self, path: str) -> int:
        """Merge entries from a JSON snapshot (improvement-only, like
        `put`); returns how many entries were absorbed."""
        with open(path) as f:
            doc = json.load(f)
        absorbed = 0
        for d in doc.get("entries", []):
            e = ChampionEntry.from_json(d)
            cur = self._by_sig.get(e.signature)
            if cur is None or e.metric < cur.metric:
                self._by_sig[e.signature] = e
                absorbed += 1
        for sig, row in (doc.get("traffic") or {}).items():
            cur = self._traffic.get(sig)
            if cur is None:
                self._traffic[sig] = dict(row)
            else:                  # merge: counts add, latest metadata wins
                cur["count"] += int(row.get("count", 0))
        return absorbed

    # --------------------------------------------------------------- stats

    def entries(self) -> List[ChampionEntry]:
        return sorted(self._by_sig.values(), key=lambda e: e.signature)

    def stats(self) -> Dict[str, Any]:
        return api.stats_payload(
            n_entries=len(self._by_sig),
            hits_exact=self.hits_exact,
            hits_sibling=self.hits_sibling,
            misses=self.misses,
            puts=self.puts,
            improvements=self.improvements,
        )
