"""Placement control plane: signature cache, stepping policies, autoscaling.

`PlacementService` pools are deliberately rigid: static config fields
(pop_size, perm_swaps, reduced, schedule, ...), the algorithm, and the
device problem are baked into each pool's compiled programs, which is what
keeps its batched step recompile-free.  The scheduler is the layer above
that restores flexibility without giving that up -- and, since PR 3, the
layer where cross-job knowledge lives:

  * **routing** -- jobs are routed by *pool signature* (device, algo,
    static config fields, gens_per_step); a `PlacementService` pool is
    created lazily the first time a signature appears, and jobs that find
    their pool full wait in a per-pool FIFO, admitting as slots free up,
  * **champion cache** (`serve.champion_store`) -- every harvested result
    writes its champion back under the problem's content signature
    (`fpga.netlist.Problem.signature`).  On `submit()` the store is
    consulted first: an exact-signature entry already meeting the job's
    `target` is served *instantly* -- a finished job, zero generations, no
    slot burned -- and otherwise the best exact-or-sibling champion is
    auto-migrated (`core.transfer.auto_migrate`) into the job's
    `init_state`, so the Table II transfer speedup happens inside the
    serving layer instead of in caller code,
  * **stepping policy** (`serve.policy`) -- each `step()` advances exactly
    one pool's batched step; *which* pool is pluggable: `round_robin`
    (default, PR 2 behaviour), `priority` (highest job priority first), or
    `deadline` (earliest deadline first over pending + inflight),
  * **autoscaling** -- with `autoscale=True`, a pool whose FIFO depth
    crosses `autoscale_threshold` is rebuilt at the next size of a
    geometric slot ladder (`PlacementService.grow`: live slots carry over;
    one step recompile per ladder size, never per job, sizes capped at
    `max_slots`).

Each pool still compiles its step once per slot-count size; per-job
results remain pure functions of (config, seed, budget, init_state) --
identical to running the same job on a standalone service -- because
pools never share PRNG streams and slot state is per-job (see
`placement_service`).  The cache changes *which* init_state a job gets,
never the result of a given spec; with no store attached the scheduler is
bitwise identical to the PR 2 router.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional, Tuple

from repro.core import hyper
from repro.core.islands import IslandConfig
from repro.fpga.netlist import Problem
from repro.runtime import telemetry
from repro.serve import api, tracing
from repro.serve import policy as P
from repro.serve.api import (FleetStats, JobRequest, JobStatus,
                             ProgressUpdate)
from repro.serve.champion_store import ChampionStore
from repro.serve.placement_service import (CONVERGENCE_TAIL, PlacementJob,
                                           PlacementService)
from repro.serve.prewarm import Prewarmer

_REG = telemetry.registry()
_M_SUBMITTED = _REG.counter(
    "repro_jobs_submitted_total", "Jobs submitted to the scheduler")
_M_CACHE_HITS = _REG.counter(
    "repro_jobs_cache_hits_total",
    "Jobs answered instantly from the champion store")
_M_FAILED = _REG.counter(
    "repro_jobs_failed_total", "Jobs surfaced as failed")
_M_CANCELLED_PENDING = _REG.counter(
    "repro_jobs_cancelled_pending_total",
    "Pending (never-admitted) jobs cancelled out of the queue")
_M_LATENCY = _REG.histogram(
    "repro_job_latency_ms", "Submit -> terminal wall ms, per layer",
    buckets=telemetry.DEFAULT_LATENCY_BUCKETS_MS)

# (device, algo, static config fields, gens_per_step, island config) --
# everything that picks a compiled program, so each pool compiles once
PoolKey = Tuple[str, str, hyper.StaticKey, int, IslandConfig]


def _default_cfg(algo: str, pop_size: Optional[int]):
    """Default config for a store-predicted pool (prediction records the
    dominant static field, pop_size; float hyperparameters don't matter --
    they are traced, not part of the compiled-program signature)."""
    from repro.core import annealing, cmaes, ga, nsga2
    cls = {"nsga2": nsga2.NSGA2Config, "ga": ga.GAConfig,
           "cmaes": cmaes.CMAESConfig, "sa": annealing.SAConfig}[algo]
    fields = {f.name for f in dataclasses.fields(cls)}
    if pop_size and "pop_size" in fields:
        return cls(pop_size=int(pop_size))
    return cls()


@dataclasses.dataclass
class FleetJob:
    """A scheduler-level job: the request, routing info, and the pool job
    once finished.  `status` is the unified lifecycle view
    (`serve.api.JobStatus`); `.done`/`.failed` survive from the PR 3 API
    (new code should read `status` -- or hold a `serve.api.JobHandle`
    from the async front-end instead of a raw FleetJob)."""
    jid: int                       # scheduler-global id
    device: str
    algo: str
    pool_key: PoolKey
    request: JobRequest            # the unified job description
    priority: float = 0.0          # higher = more urgent (priority policy)
    deadline: Optional[float] = None   # smaller = sooner (deadline policy)
    pool_jid: Optional[int] = None  # set at admission
    result: Optional[PlacementJob] = None
    cached: bool = False           # served straight from the champion store
    warm_from_cache: bool = False  # init_state injected by the store
    cancelled: bool = False        # cancelled before completion
    error: Optional[str] = None    # last admission-failure note (re-queued)
    attempts: int = 0              # failed admission attempts so far
    t_submit: float = 0.0          # monotonic submit time (latency hist);
    #                                zeroed once the terminal latency is
    #                                observed so a job records exactly once

    @property
    def trace_id(self) -> Optional[str]:
        return self.request.trace_id

    @property
    def status(self) -> JobStatus:
        if self.cancelled:
            return JobStatus.CANCELLED
        if self.result is not None and self.result.done:
            return JobStatus.DONE
        if self.failed:
            return JobStatus.FAILED
        if self.pool_jid is not None:
            return JobStatus.RUNNING
        return JobStatus.QUEUED

    @property
    def spec(self) -> Dict[str, Any]:
        """Deprecated PR 3-8 view of the request as submit() kwargs."""
        return {"cfg": self.request.cfg, "seed": self.request.seed,
                "budget": self.request.budget,
                "target": self.request.target,
                "init_state": self.request.init_state,
                "jitter": self.request.jitter,
                "sigma_shrink": self.request.sigma_shrink}

    @property
    def done(self) -> bool:
        return self.result is not None and self.result.done

    @property
    def failed(self) -> bool:
        """Gave up after repeated admission failures (never silently
        dropped: the error note says why)."""
        return self.result is None and self.error is not None \
            and self.attempts >= PlacementScheduler.ADMIT_RETRIES


class PlacementScheduler:
    """Routes placement jobs across lazily created per-signature pools.

    `store=ChampionStore(...)` turns on the champion cache, `policy=`
    selects the stepping policy ("round_robin" / "priority" / "deadline"
    or a `serve.policy.SteppingPolicy` instance), and `autoscale=True`
    lets queue depth grow pools along a geometric slot ladder.
    """

    # admission attempts per job before it is surfaced as failed (attempt
    # 1 = the original; each re-queue carries an error note)
    ADMIT_RETRIES = 3

    def __init__(self, problems: Optional[Dict[str, Problem]] = None,
                 n_slots: int = 4, gens_per_step: int = 4, seed: int = 0,
                 policy="round_robin", store: Optional[ChampionStore] = None,
                 autoscale: bool = False,
                 autoscale_threshold: Optional[int] = None,
                 max_slots: Optional[int] = None,
                 prewarm: bool = False,
                 prewarmer: Optional[Prewarmer] = None):
        self.n_slots, self.gens_per_step = n_slots, gens_per_step
        self.seed = seed
        self.policy = P.get_policy(policy)
        self.store = store
        self.autoscale = autoscale
        # `prewarm=True` attaches a background AOT compiler
        # (`serve.prewarm.Prewarmer`): predicted / explicitly requested
        # pools build off-thread and `grow()` sizes pre-compile, so the
        # stepping loop stops blocking on XLA
        self.prewarmer = prewarmer if prewarmer is not None else (
            Prewarmer() if prewarm else None)
        # default trigger: a full extra wave of jobs waiting behind the pool
        self.autoscale_threshold = (n_slots if autoscale_threshold is None
                                    else autoscale_threshold)
        self.max_slots = 4 * n_slots if max_slots is None else max_slots
        self.autoscale_events: List[Tuple[str, int, int]] = []
        self._problems: Dict[str, Problem] = dict(problems or {})
        self._pools: Dict[PoolKey, PlacementService] = {}
        self._pending: Dict[PoolKey, List[FleetJob]] = {}
        self._inflight: Dict[Tuple[PoolKey, int], FleetJob] = {}
        self._rotation: List[PoolKey] = []     # stable pool order
        self._cached_done: List[FleetJob] = []  # instant cache hits to drain
        self._failed: List[FleetJob] = []      # gave up admitting; drained
        self.next_jid = 0
        self.jobs: Dict[int, FleetJob] = {}
        # fleet-level submit -> terminal latency (stats(); the registry
        # histogram aggregates across scheduler instances under a layer
        # label)
        self._latency_hist = telemetry.Histogram(
            "job_latency_ms", buckets=telemetry.DEFAULT_LATENCY_BUCKETS_MS)

    # ------------------------------------------------------------ routing

    def problem(self, device_name: str) -> Problem:
        """The (cached) placement problem for a device name."""
        if device_name not in self._problems:
            from repro.fpga import device, netlist
            self._problems[device_name] = netlist.make_problem(
                device.get_device(device_name))
        return self._problems[device_name]

    def pool_key(self, device_name: str, algo: str, cfg,
                 gens_per_step: Optional[int] = None,
                 islands: Optional[IslandConfig] = None) -> PoolKey:
        static_key, _ = hyper.split_config(cfg)
        return (device_name, algo, static_key,
                gens_per_step or self.gens_per_step,
                islands or IslandConfig())

    def _builder(self, key: PoolKey, cfg):
        """The one true pool constructor for a signature: the synchronous
        path and the background prewarm build share it, which is what
        makes an adopted pool bitwise identical to a cold one (same
        constructor arguments, same seed)."""
        device_name, algo, _static, gps, icfg = key

        def build() -> PlacementService:
            return PlacementService(
                self.problem(device_name), cfg, algo=algo,
                n_slots=self.n_slots, gens_per_step=gps,
                seed=self.seed, islands=icfg,
                label=self._label(key))
        return build

    def _pool(self, key: PoolKey, cfg) -> PlacementService:
        if key not in self._pools:
            svc = (self.prewarmer.take(key)
                   if self.prewarmer is not None else None)
            if svc is not None and tracing.enabled():
                tracing.tracer().instant("pool.prewarm_adopt",
                                         pool=svc.label)
            if svc is None:    # not prewarmed (or its build failed): cold
                svc = self._builder(key, cfg)()
            self._pools[key] = svc
            self._pending[key] = []
            self._rotation.append(key)
            if (self.prewarmer is not None and self.autoscale
                    and 2 * svc.n_slots <= self.max_slots):
                # pre-compile the next ladder size before the queue backs
                # up, so an eventual grow() is pure host-side surgery
                self.prewarmer.prewarm_grow(svc, 2 * svc.n_slots)
        return self._pools[key]

    # ------------------------------------------------------------ prewarm

    def prewarm(self, device: str, cfg, algo: str = "nsga2",
                gens_per_step: Optional[int] = None,
                islands: Optional[IslandConfig] = None) -> PoolKey:
        """Request a background build of the pool for this signature (the
        pool a later `submit()` with the same arguments would create).
        No-op without a prewarmer or when the pool already exists."""
        key = self.pool_key(device, algo, cfg, gens_per_step, islands)
        if self.prewarmer is not None and key not in self._pools:
            self.prewarmer.prewarm_pool(key, self._builder(key, cfg))
        return key

    def prewarm_predicted(self, top_k: int = 4) -> List[PoolKey]:
        """Prewarm the pools the champion store's signature-traffic
        distribution predicts: a restarted process starts compiling its
        historical working set before the first job arrives."""
        if self.store is None or self.prewarmer is None:
            return []
        keys = []
        for pred in self.store.predicted_keys(top_k):
            try:
                cfg = _default_cfg(pred.algo, pred.pop_size)
            except KeyError:
                continue                        # unknown algo in old JSON
            keys.append(self.prewarm(pred.device_name, cfg,
                                     algo=pred.algo))
        return keys

    # -------------------------------------------------------------- cache

    def _consult_store(self, job: FleetJob, problem: Problem) -> bool:
        """Champion-store fast paths for a submitted job.

        Returns True when the job was answered instantly (exact-signature
        entry already meeting its `target`: finished result, zero
        generations, no pool touched).  Otherwise injects the best
        exact-or-sibling champion as the job's `init_state` (unless the
        caller supplied one) and returns False so the job runs warm.
        """
        entry, kind = self.store.lookup(problem)
        if entry is None:
            return False
        target = job.request.target
        if kind == "exact" and target is not None and entry.metric <= target:
            job.result = PlacementJob(
                jid=-1, cfg=job.request.cfg, seed=job.request.seed,
                budget=0, target=target, gens=0, done=True,
                best_objs=entry.best_objs.copy(), metric=entry.metric,
                genotype={t: tuple(a.copy() for a in leaves)
                          for t, leaves in entry.genotype.items()})
            job.cached = True
            self._cached_done.append(job)
            return True
        if job.request.init_state is None:
            job.request = job.request.replace(
                init_state=self.store.seed_for(
                    problem, entry, problem_of=self.problem))
            job.warm_from_cache = True
        return False

    def _write_back(self, job: FleetJob, problem: Problem) -> None:
        pj = job.result
        self.store.put(problem, pj.genotype, pj.metric, pj.best_objs,
                       provenance={"device": job.device, "algo": job.algo,
                                   "seed": pj.seed, "gens": pj.gens,
                                   "fleet_jid": job.jid})

    # ------------------------------------------------------------- admit

    def submit(self, device=None, cfg=None, algo: str = "nsga2",
               gens_per_step: Optional[int] = None, priority: float = 0.0,
               deadline: Optional[float] = None,
               islands: Optional[IslandConfig] = None, **spec) -> int:
        """Enqueue one job; returns its scheduler-global jid.

        The canonical form is `submit(request)` with a
        `serve.api.JobRequest` as the only argument; the kwarg form
        survives as a deprecated shim that builds the same request
        (results are bitwise identical).

        Unlike a raw pool, this never rejects: a full pool queues the job
        FIFO and admits it when a slot frees.  `priority` / `deadline`
        only matter to the matching stepping policies (they bias
        completion order, never results).  With a champion store attached,
        an exact-signature cache hit meeting `target` finishes the job
        immediately -- no pool is created and no slot is burned -- and any
        other exact-or-sibling champion warm-starts it via `init_state`
        injection.  `islands` routes the job to an island-model pool
        (`core.islands`): island topology is part of the pool signature,
        so islands and single-population traffic for the same config
        coexist in separate pools, each still compiling once.
        """
        if isinstance(device, JobRequest):
            request = device
        else:
            request = api.deprecated_kwargs_request(
                "PlacementScheduler", device=device, cfg=cfg, algo=algo,
                gens_per_step=gens_per_step, priority=priority,
                deadline=deadline, islands=islands, **spec)
        return self.submit_request(request)

    def submit_request(self, request: JobRequest) -> int:
        """`submit()` on the unified request type (no shim, no warning)."""
        if request.device is None:
            raise ValueError("JobRequest.device is required at the "
                             "scheduler (it picks the problem and pool)")
        if request.cfg is None:
            raise ValueError("JobRequest.cfg is required at the scheduler "
                             "(pools have no shared base config)")
        device, algo = request.device, request.algo
        cfg = request.resolved_cfg()
        if cfg is not request.cfg:          # fused override applied
            request = request.replace(cfg=cfg, fused=None)
        traced_on = tracing.enabled()
        if traced_on and request.trace_id is None:
            # outermost traced layer for this request: mint + announce
            # (the front-end mints first when it is above us)
            request = request.replace(trace_id=tracing.new_trace_id())
            tracing.tracer().instant("job.submit", request.trace_id,
                                     device=device, algo=algo,
                                     budget=request.budget)
        key = self.pool_key(device, algo, cfg, request.gens_per_step,
                            request.islands)
        job = FleetJob(self.next_jid, device, algo, key, request=request,
                       priority=request.priority,
                       deadline=request.deadline,
                       t_submit=time.monotonic())
        self.next_jid += 1
        self.jobs[job.jid] = job
        _M_SUBMITTED.inc()
        if self.store is not None:
            problem = self.problem(device)
            # signature-traffic bookkeeping: what `prewarm_predicted`
            # mines after a restart (persists with the store JSON)
            self.store.note_traffic(
                problem, algo=algo,
                pop_size=getattr(cfg, "pop_size", None))
            if self._consult_store(job, problem):
                _M_CACHE_HITS.inc()
                self._observe_terminal(job)    # cache hits are terminal too
                if traced_on:
                    tracing.tracer().instant(
                        "job.cache_hit", job.trace_id,
                        metric=job.result.metric)
                return job.jid             # served from cache, zero slots
        self._pool(key, cfg)               # create lazily
        if traced_on:
            tracing.tracer().instant("job.queued", job.trace_id,
                                     pool=self._label(key),
                                     queue_depth=len(self._pending[key]))
        self._pending[key].append(job)
        if len(self._pending[key]) == 1:   # a waiting head means pool full
            self._admit(key)
        return job.jid

    # ------------------------------------------------------------- cancel

    def cancel(self, jid: int) -> bool:
        """Cancel a job at the next step boundary: a pending job leaves
        its FIFO, an in-flight job's slot is freed and refilled from the
        queue.  Returns False when the job is already terminal.  Like
        `PlacementService.cancel`, call between `step()`s -- the async
        front-end executes cancels on its stepping thread, which
        guarantees the boundary."""
        job = self.jobs.get(jid)
        if job is None or job.status.terminal:
            return False
        if job.pool_jid is not None:       # in flight: free the slot
            self._inflight.pop((job.pool_key, job.pool_jid), None)
            # the pool emits the job.cancelled trace event + counter
            self._pools[job.pool_key].cancel(job.pool_jid)
            job.cancelled = True
            self._observe_terminal(job)
            self._admit(job.pool_key)      # the freed slot refills now
            return True
        # pending (or cached-but-undrained): pull it out of the queue
        queue = self._pending.get(job.pool_key)
        if queue is not None and job in queue:
            queue.remove(job)
            job.cancelled = True
            self._observe_terminal(job)
            _M_CANCELLED_PENDING.inc()
            if tracing.enabled():
                tracing.tracer().instant("job.cancelled", job.trace_id,
                                         pending=True)
            return True
        if job in self._cached_done:       # cache hit not yet drained:
            return False                   # already answered, too late
        return False

    def _admit(self, key: PoolKey) -> None:
        """Drain the pool's FIFO head into free slots: O(jobs admitted),
        with an O(1) early-out when the pool is already full.

        Resilient to a job whose admission raises (a seed genotype that
        fails canonicalization, a pool left inconsistent by a failed
        prewarm, ...): the job is RE-QUEUED at the back with an error note
        instead of being dropped or wedging the FIFO head, and after
        `ADMIT_RETRIES` failed attempts it is surfaced as failed via
        `step()` so `run_all()` still terminates and co-queued jobs keep
        flowing."""
        pool, queue = self._pools[key], self._pending[key]
        admissible = len(queue)            # each job gets one try per drain
        while queue and admissible > 0 and not pool.active.all():
            admissible -= 1
            job = queue[0]
            try:
                pool_jid = pool.submit_request(job.request)
            except Exception as e:         # noqa: BLE001 -- never drop a job
                queue.pop(0)
                job.attempts += 1
                job.error = (f"admission to pool failed "
                             f"(attempt {job.attempts}): "
                             f"{type(e).__name__}: {e}")
                if job.attempts >= self.ADMIT_RETRIES:
                    self._failed.append(job)   # drained by step()
                    _M_FAILED.inc()
                    if tracing.enabled():
                        tracing.tracer().instant(
                            "job.failed", job.trace_id,
                            error=job.error, attempts=job.attempts)
                else:
                    queue.append(job)          # re-queued, not dropped
                continue
            if pool_jid is None:           # pool full
                break
            queue.pop(0)
            job.pool_jid = pool_jid
            self._inflight[(key, pool_jid)] = job

    def _maybe_grow(self, key: PoolKey) -> None:
        """Queue-depth autoscaling: double the pool along the geometric
        slot ladder (n0, 2*n0, 4*n0, ... <= max_slots) when its FIFO
        backs up.  Doubling keeps the compile count O(log max/n0) while
        absorbing any sustained burst."""
        pool = self._pools[key]
        if (len(self._pending[key]) >= self.autoscale_threshold
                and 2 * pool.n_slots <= self.max_slots):
            old = pool.n_slots
            pool.grow(2 * old)
            self.autoscale_events.append((self._label(key), old,
                                          pool.n_slots))
            if (self.prewarmer is not None
                    and 2 * pool.n_slots <= self.max_slots):
                # keep one ladder rung ahead of the traffic
                self.prewarmer.prewarm_grow(pool, 2 * pool.n_slots)
            self._admit(key)               # the new slots fill immediately

    # -------------------------------------------------------------- step

    @property
    def busy(self) -> bool:
        return (bool(self._inflight) or bool(self._cached_done)
                or bool(self._failed) or any(self._pending.values()))

    def _views(self) -> List[P.PoolView]:
        by_pool: Dict[PoolKey, List[FleetJob]] = {k: [] for k
                                                  in self._rotation}
        for (key, _), job in self._inflight.items():
            by_pool[key].append(job)
        views = []
        for i, key in enumerate(self._rotation):
            pending = self._pending[key]
            views.append(P.PoolView(
                key=key, index=i,
                steppable=bool(self._pools[key].active.any()),
                queue_depth=len(pending),
                jobs=by_pool[key] + pending))
        return views

    def _observe_terminal(self, job: FleetJob) -> None:
        """Record the job's submit -> terminal latency exactly once
        (`t_submit` is zeroed after observing)."""
        if job.t_submit <= 0.0:
            return
        ms = (time.monotonic() - job.t_submit) * 1e3
        job.t_submit = 0.0
        self._latency_hist.observe(ms)
        _M_LATENCY.observe(ms, layer="fleet")

    def step(self) -> List[FleetJob]:
        """Admit what fits everywhere (growing backed-up pools when
        autoscaling), let the policy pick ONE pool, advance its batched
        step; returns newly finished fleet jobs (instant cache hits are
        drained here too)."""
        finished, self._cached_done = self._cached_done, []
        finished += self._failed           # surfaced, never silently lost
        self._failed = []
        for key in self._rotation:
            if self._pending[key]:
                if self.autoscale:
                    self._maybe_grow(key)
                self._admit(key)
        i = self.policy.select(self._views())
        if i is not None:
            key = self._rotation[i]
            pool = self._pools[key]
            for pj in pool.step():
                job = self._inflight.pop((key, pj.jid))
                job.result = pj
                if self.store is not None:
                    self._write_back(job, self.problem(job.device))
                finished.append(job)
            self._admit(key)               # freed slots refill now
        for job in finished:
            self._observe_terminal(job)
        return finished

    def run_all(self) -> List[FleetJob]:
        """Step until every submitted job finishes (admission order may
        interleave pools; per-job results don't depend on it)."""
        done: List[FleetJob] = []
        while self.busy:
            done.extend(self.step())
        return done

    def progress(self) -> List[ProgressUpdate]:
        """Generation-boundary snapshot of every in-flight job (the async
        front-end streams these through `JobHandle.progress()` after each
        `step()`; ETA extrapolation is the front-end's job -- the
        scheduler reports ground truth only)."""
        out: List[ProgressUpdate] = []
        for (key, pool_jid), job in list(self._inflight.items()):
            pj = self._pools[key].job(pool_jid)
            if pj is None:
                continue
            out.append(ProgressUpdate(
                jid=job.jid, status=JobStatus.RUNNING, gens=pj.gens,
                budget=pj.budget, metric=pj.metric,
                best_objs=pj.best_objs,
                convergence=tuple(
                    list(pj.history)[-CONVERGENCE_TAIL:])))
        return out

    # ------------------------------------------------------------ closing

    def close(self) -> None:
        """Orderly shutdown of the attached background machinery: stop
        (and join) the prewarm worker and persist the champion store when
        it was constructed with a path.  Idempotent; in-flight jobs are
        NOT waited for -- drain with `run_all()` (or the front-end's
        `drain()`) first."""
        if self.prewarmer is not None:
            self.prewarmer.close()
        if self.store is not None and self.store.path is not None:
            self.store.save()

    # -------------------------------------------------------------- stats

    def _label(self, key: PoolKey) -> str:
        device_name, algo, static_key, gps, icfg = key
        label = f"{device_name}/{algo}/" + ",".join(
            f"{k}={v}" for k, v in static_key[1]) + f"/gps={gps}"
        if icfg.active:
            label += f"/isl={icfg.n_islands}x{icfg.migrate_every}"
        return label

    def stats(self) -> FleetStats:
        pools = {}
        for key in self._rotation:
            pools[self._label(key)] = dict(
                self._pools[key].stats(),
                queue_depth=len(self._pending[key]))
        statuses = [j.status for j in self.jobs.values()]
        out = api.stats_payload(
            n_pools=len(self._pools),
            jobs_submitted=self.next_jid,
            jobs_done=sum(s is JobStatus.DONE for s in statuses),
            jobs_failed=sum(s is JobStatus.FAILED for s in statuses),
            jobs_cancelled=sum(s is JobStatus.CANCELLED
                               for s in statuses),
            policy=getattr(self.policy, "name", type(self.policy).__name__),
            autoscale_events=list(self.autoscale_events),
            pools=pools,
            # --- appended under schema_version 2 (observability) ---
            job_latency_ms_hist=self._latency_hist.to_dict(),
        )
        if self.store is not None:
            out["cache"] = self.store.stats()
        if self.prewarmer is not None:
            out["prewarm"] = self.prewarmer.stats()
        return out
