"""Multi-pool placement scheduler: heterogeneous jobs, one process.

`PlacementService` pools are deliberately rigid: static config fields
(pop_size, perm_swaps, reduced, schedule, ...), the algorithm, and the
device problem are baked into each pool's compiled programs, which is what
keeps its batched step recompile-free.  The scheduler is the layer above
that restores flexibility without giving that up:

  * jobs are routed by *pool signature* -- (device, algo, static config
    fields, gens_per_step) -- and a `PlacementService` pool is created
    lazily the first time a signature appears,
  * pools step round-robin (one pool's batched step per `step()` call), so
    a process can race NSGA-II vs CMA-ES vs SA across pop sizes and
    devices with fair interleaving on one accelerator,
  * jobs that find their pool full wait in a per-pool FIFO and admit as
    slots free up (the pool's own backpressure, made non-blocking).

Each pool still compiles its step exactly once; per-job results remain
pure functions of (config, seed, budget, init_state) -- identical to
running the same job on a standalone service -- because pools never share
PRNG streams and slot state is per-job (see `placement_service`).

Warm starts compose: `submit(init_state=...)` forwards the seed genotype
to the routed pool, so a single migrated champion can fan out across every
device pool in the fleet (see `examples/placement_fleet.py`).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

from repro.core import hyper
from repro.fpga.netlist import Problem
from repro.serve.placement_service import PlacementJob, PlacementService

PoolKey = Tuple[str, str, hyper.StaticKey, int]


@dataclasses.dataclass
class FleetJob:
    """A scheduler-level job: routing info + the pool job once finished."""
    jid: int                       # scheduler-global id
    device: str
    algo: str
    pool_key: PoolKey
    spec: Dict[str, Any]           # PlacementService.submit kwargs
    pool_jid: Optional[int] = None  # set at admission
    result: Optional[PlacementJob] = None

    @property
    def done(self) -> bool:
        return self.result is not None and self.result.done


class PlacementScheduler:
    """Routes placement jobs across lazily created per-signature pools."""

    def __init__(self, problems: Optional[Dict[str, Problem]] = None,
                 n_slots: int = 4, gens_per_step: int = 4, seed: int = 0):
        self.n_slots, self.gens_per_step = n_slots, gens_per_step
        self.seed = seed
        self._problems: Dict[str, Problem] = dict(problems or {})
        self._pools: Dict[PoolKey, PlacementService] = {}
        self._pending: Dict[PoolKey, List[FleetJob]] = {}
        self._inflight: Dict[Tuple[PoolKey, int], FleetJob] = {}
        self._rotation: List[PoolKey] = []     # round-robin order
        self._next_pool = 0
        self.next_jid = 0
        self.jobs: Dict[int, FleetJob] = {}

    # ------------------------------------------------------------ routing

    def problem(self, device_name: str) -> Problem:
        """The (cached) placement problem for a device name."""
        if device_name not in self._problems:
            from repro.fpga import device, netlist
            self._problems[device_name] = netlist.make_problem(
                device.get_device(device_name))
        return self._problems[device_name]

    def pool_key(self, device_name: str, algo: str, cfg,
                 gens_per_step: Optional[int] = None) -> PoolKey:
        static_key, _ = hyper.split_config(cfg)
        return (device_name, algo, static_key,
                gens_per_step or self.gens_per_step)

    def _pool(self, key: PoolKey, cfg) -> PlacementService:
        if key not in self._pools:
            device_name, algo, _static, gps = key
            self._pools[key] = PlacementService(
                self.problem(device_name), cfg, algo=algo,
                n_slots=self.n_slots, gens_per_step=gps,
                seed=self.seed)
            self._pending[key] = []
            self._rotation.append(key)
        return self._pools[key]

    # ------------------------------------------------------------- admit

    def submit(self, device: str, cfg, algo: str = "nsga2",
               gens_per_step: Optional[int] = None, **spec) -> int:
        """Enqueue one job; returns its scheduler-global jid.

        `spec` is forwarded to `PlacementService.submit` (seed, budget,
        target, init_state, jitter, sigma_shrink).  Unlike a raw pool,
        this never rejects: a full pool queues the job FIFO and admits it
        when a slot frees.
        """
        key = self.pool_key(device, algo, cfg, gens_per_step)
        self._pool(key, cfg)                   # create lazily
        job = FleetJob(self.next_jid, device, algo, key,
                       spec=dict(spec, cfg=cfg))
        self.next_jid += 1
        self.jobs[job.jid] = job
        self._pending[key].append(job)
        self._admit(key)
        return job.jid

    def _admit(self, key: PoolKey) -> None:
        pool, queue = self._pools[key], self._pending[key]
        while queue:
            pool_jid = pool.submit(**queue[0].spec)
            if pool_jid is None:               # pool full
                break
            job = queue.pop(0)
            job.pool_jid = pool_jid
            self._inflight[(key, pool_jid)] = job

    # -------------------------------------------------------------- step

    @property
    def busy(self) -> bool:
        return bool(self._inflight) or any(self._pending.values())

    def step(self) -> List[FleetJob]:
        """Admit what fits everywhere, then advance ONE pool (round-robin)
        by its batched step; returns newly finished fleet jobs."""
        for key in self._rotation:
            self._admit(key)
        finished: List[FleetJob] = []
        for _ in range(len(self._rotation)):
            key = self._rotation[self._next_pool % len(self._rotation)]
            self._next_pool += 1
            pool = self._pools[key]
            if not pool.active.any():
                continue
            for pj in pool.step():
                job = self._inflight.pop((key, pj.jid))
                job.result = pj
                finished.append(job)
            self._admit(key)                   # freed slots refill now
            break
        return finished

    def run_all(self) -> List[FleetJob]:
        """Step until every submitted job finishes (admission order may
        interleave pools; per-job results don't depend on it)."""
        done: List[FleetJob] = []
        while self.busy:
            done.extend(self.step())
        return done

    # -------------------------------------------------------------- stats

    def stats(self) -> Dict[str, Any]:
        pools = {}
        for key in self._rotation:
            device_name, algo, static_key, gps = key
            label = f"{device_name}/{algo}/" + ",".join(
                f"{k}={v}" for k, v in static_key[1]) + f"/gps={gps}"
            pools[label] = self._pools[key].stats()
        return {
            "n_pools": len(self._pools),
            "jobs_submitted": self.next_jid,
            "jobs_done": sum(j.done for j in self.jobs.values()),
            "pools": pools,
        }
