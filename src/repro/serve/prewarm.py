"""AOT pool prewarming: compile off the stepping loop, adopt when ready.

The scheduler's pools compile lazily -- the first job of a new signature,
and every geometric-ladder `grow()`, blocks the stepping loop on XLA.
With the persistent compilation cache (`runtime.compile_cache`) a
*restarted* process stops paying that bill; this module removes it from a
*running* one:

  * `prewarm_pool(key, builder)` -- build a complete `PlacementService`
    for a pool signature on the worker thread (its init/fill/step programs
    compile there); `PlacementScheduler._pool` adopts the finished pool
    via `take(key)` instead of constructing synchronously,
  * `prewarm_grow(pool, n_slots)` -- run `pool.prewarm_size(n_slots)` on
    the worker thread, so the pool's jitted step (same function instance,
    bigger slot shape) is already in the in-memory jit cache when the
    autoscaler's `grow()` lands,
  * predictions -- the `ChampionStore` records signature traffic
    (`note_traffic`/`predicted_keys`), so a fresh process can prewarm the
    pools its historical traffic says are coming
    (`PlacementScheduler.prewarm_predicted`).

Correctness contract: prewarming only moves *compilation* between
threads.  A prewarmed pool is built by the exact builder the scheduler
would have called synchronously (same constructor arguments, same seed),
so per-job results stay pure functions of (config, seed, budget,
init_state) -- bitwise identical to a cold pool.  A failed background
build is recorded (`errors`) and `take()` returns None, so the scheduler
falls back to synchronous creation: prewarm failures cost latency, never
jobs.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from typing import Any, Callable, Dict, Optional, Tuple

from repro.serve import api


class Prewarmer:
    """Single worker thread executing pool builds / grow prewarms FIFO.

    One worker is deliberate: compilation is process-global (jit caches,
    persistent cache) and the point is to overlap compile with *stepping*,
    not to compile in parallel with itself.  The thread is a daemon and
    starts lazily on the first task.
    """

    def __init__(self, name: str = "pool-prewarm"):
        self._cv = threading.Condition()
        self._tasks: deque = deque()           # (kind, tag, thunk)
        self._inflight: Optional[Tuple[str, Any]] = None
        self._ready: Dict[Any, Any] = {}       # pool key -> built pool
        self._known: set = set()               # tags ever enqueued
        self.errors: Dict[str, str] = {}       # repr(tag) -> error note
        self.builds_done = 0
        self.grows_done = 0
        self.failures = 0
        self.adopted = 0
        self._name = name
        self._thread: Optional[threading.Thread] = None
        self._stop = False

    # ----------------------------------------------------------- enqueue

    def _ensure_thread(self) -> None:
        if self._thread is None or not self._thread.is_alive():
            self._stop = False
            self._thread = threading.Thread(target=self._loop,
                                            name=self._name, daemon=True)
            self._thread.start()

    def _enqueue(self, kind: str, tag: Any, thunk: Callable[[], Any]
                 ) -> bool:
        with self._cv:
            if tag in self._known:
                return False                   # already queued/built/failed
            self._known.add(tag)
            self._tasks.append((kind, tag, thunk))
            self._cv.notify_all()
        self._ensure_thread()
        return True

    def prewarm_pool(self, key: Any, builder: Callable[[], Any]) -> bool:
        """Schedule a background pool build for `key`; returns False when
        the key was already requested (dedup, not an error)."""
        return self._enqueue("build", key, builder)

    def prewarm_grow(self, pool: Any, n_slots: int) -> bool:
        """Schedule `pool.prewarm_size(n_slots)` on the worker thread."""
        tag = ("grow", id(pool), int(n_slots))
        return self._enqueue("grow", tag,
                             lambda: pool.prewarm_size(n_slots))

    # ------------------------------------------------------------ consume

    def take(self, key: Any) -> Optional[Any]:
        """Pop the finished pool for `key` (None while building, after a
        failed build, or when never requested -- callers fall back to a
        synchronous build in every None case)."""
        with self._cv:
            pool = self._ready.pop(key, None)
            if pool is not None:
                self.adopted += 1
            return pool

    def pending(self, key: Any) -> bool:
        """True while `key`'s build is queued or running."""
        with self._cv:
            if self._inflight is not None and self._inflight[1] == key:
                return True
            return any(tag == key for _, tag, _ in self._tasks)

    def wait_idle(self, timeout: float = 120.0) -> bool:
        """Block until the queue drains (tests / orderly shutdown)."""
        deadline = time.monotonic() + timeout
        with self._cv:
            while self._tasks or self._inflight is not None:
                left = deadline - time.monotonic()
                if left <= 0:
                    return False
                self._cv.wait(left)
            return True

    def stop(self) -> None:
        with self._cv:
            self._stop = True
            self._cv.notify_all()

    def close(self, timeout: float = 30.0) -> None:
        """Orderly shutdown: stop accepting work and join the worker.

        The in-flight task (if any) runs to completion -- interrupting an
        XLA compile mid-flight is not safe -- but queued tasks are
        abandoned.  Idempotent; safe to call when the thread never started.
        """
        with self._cv:
            self._tasks.clear()
            self._stop = True
            self._cv.notify_all()
        t = self._thread
        if t is not None and t.is_alive():
            t.join(timeout)

    # ------------------------------------------------------------- worker

    def _loop(self) -> None:
        while True:
            with self._cv:
                while not self._tasks and not self._stop:
                    self._cv.wait()
                if self._stop:
                    return
                kind, tag, thunk = self._tasks.popleft()
                self._inflight = (kind, tag)
            try:
                out = thunk()
                with self._cv:
                    if kind == "build":
                        self._ready[tag] = out
                        self.builds_done += 1
                    else:
                        self.grows_done += 1
            except Exception as e:             # noqa: BLE001 -- a failed
                # prewarm must never kill the worker; the scheduler falls
                # back to a synchronous build and the error is surfaced
                with self._cv:
                    self.failures += 1
                    self.errors[repr(tag)] = f"{type(e).__name__}: {e}"
            finally:
                with self._cv:
                    self._inflight = None
                    self._cv.notify_all()

    # -------------------------------------------------------------- stats

    def stats(self) -> Dict[str, Any]:
        with self._cv:
            return api.stats_payload(
                builds_done=self.builds_done,
                grows_done=self.grows_done,
                adopted=self.adopted,
                failures=self.failures,
                queued=len(self._tasks),
                ready=len(self._ready),
                errors=dict(self.errors),
            )
