"""The serve-layer public API: one request type, one handle type, one
versioned stats schema.

Until PR 9 the serve layer had two drifted `submit()` signatures
(`PlacementService.submit(cfg=, seed=, budget=, ...)` vs
`PlacementScheduler.submit(device, cfg, algo=, priority=, ...)`), callers
poked `job.result is not None` / `j.done` to learn job state, and
`stats()` returned ad-hoc dicts whose keys drifted per layer.  This module
is the single place the serving surface is defined:

  * **`JobRequest`** -- a frozen dataclass describing one placement job
    end to end (device, cfg, algo, seed, budget, target, priority,
    deadline, init_state, islands, fused, ...).  Both
    `PlacementService.submit` and `PlacementScheduler.submit` accept it;
    their old kwarg forms remain as thin shims that build a `JobRequest`
    and emit a `DeprecationWarning`.  A request is *pure data*: submitting
    the same request always produces bitwise the same result, whichever
    entry point or concurrency level carried it.
  * **`JobStatus` / `JobHandle`** -- the one job-lifecycle surface:
    `.status` (QUEUED / RUNNING / DONE / FAILED / CANCELLED),
    `.result(timeout=...)`, `.exception()`, `.cancel()`, and -- when the
    handle is served by the asyncio front-end (`serve.frontend`) --
    `await handle.wait()` and `async for update in handle.progress()`.
    The pre-PR attributes (`.done`, `.failed`) survive as deprecated
    properties so PR 1-8 call sites keep running.
  * **`ServiceStats` / `FleetStats` / `FrontendStats`** -- TypedDicts
    documenting every key `stats()` returns, stamped with
    `schema_version = STATS_SCHEMA_VERSION` so bench tooling
    (`benchmarks/bench_service.py`, `benchmarks/check_bench.py`) can read
    typed keys instead of guessing.

Nothing here touches jitted code: the API layer is pure host-side
bookkeeping, so adopting it cannot change placement results.
"""
from __future__ import annotations

import collections
import dataclasses
import enum
import threading
import warnings
from typing import Any, Dict, List, Optional, Tuple

from typing import TypedDict

from repro.core.islands import IslandConfig

# bump when a stats key is renamed or changes meaning; ADDING keys is the
# normal append-only path and does not bump the version.
# v1 -> v2 (observability PR): no key was renamed or removed -- v2 marks
# the point where every layer's stats() carries the telemetry keys
# (latency histograms, convergence tails) and where the previously
# unversioned Prewarmer/ChampionStore dicts joined the versioned schema
# via `stats_payload()`.  v1 readers keep working on every old key.
STATS_SCHEMA_VERSION = 2


def stats_payload(**keys: Any) -> Dict[str, Any]:
    """The one way a serve-layer `stats()` builds its dict: stamps
    `schema_version` as the first key so the five builders
    (service/scheduler/frontend/prewarmer/champion store) cannot drift
    apart on the envelope.  Keys stay append-only per the bench contract.
    """
    out: Dict[str, Any] = {"schema_version": STATS_SCHEMA_VERSION}
    out.update(keys)
    return out


class JobStatus(enum.Enum):
    """Lifecycle of one placement job, whichever layer serves it."""

    QUEUED = "queued"          # accepted; waiting for a slot
    RUNNING = "running"        # occupying a pool slot, evolving
    DONE = "done"              # harvested; result available
    FAILED = "failed"          # admission/stepping error; exception set
    CANCELLED = "cancelled"    # cancelled before completion; slot freed

    @property
    def terminal(self) -> bool:
        return self in (JobStatus.DONE, JobStatus.FAILED,
                        JobStatus.CANCELLED)


class QueueFull(RuntimeError):
    """Non-blocking admission found the bounded queue at capacity."""


class JobFailedError(RuntimeError):
    """A job was surfaced as failed (repeated admission errors or a
    stepping-thread crash); the message carries the original note."""


class JobCancelledError(RuntimeError):
    """`result()` was called on a cancelled job."""


@dataclasses.dataclass(frozen=True, eq=False)
class JobRequest:
    """Everything that defines one placement job, as pure data.

    The result of a request is a pure function of
    (cfg, seed, budget, init_state, jitter, sigma_shrink) -- admission
    timing, co-tenant jobs, priorities and deadlines change *latency
    only*, never the answer (see `serve.placement_service`).

    Fields consumed by both layers: `cfg`, `seed`, `budget`, `target`,
    `init_state`, `jitter`, `sigma_shrink`, `fused`.  Scheduler-level
    routing fields (`device`, `algo`, `gens_per_step`, `islands`) and
    policy fields (`priority`, `deadline`) are ignored by a bare
    `PlacementService` pool except for validation (a request whose `algo`
    or `islands` disagrees with the pool is rejected loudly, not
    silently re-routed).

    `fused=None` leaves the config's own `fused` flag alone; True/False
    overrides it via `dataclasses.replace` (static pool identity, see
    `kernels.fused_eval`).  `seed=None` lets the admitting layer pick its
    deterministic default (the job id); pass an explicit seed whenever
    you care about reproducibility across submission orders.
    """

    device: Optional[str] = None
    cfg: Any = None
    algo: str = "nsga2"
    seed: Optional[int] = None
    budget: int = 64
    target: Optional[float] = None
    priority: float = 0.0
    deadline: Optional[float] = None
    init_state: Any = None
    islands: Optional[IslandConfig] = None
    fused: Optional[bool] = None
    gens_per_step: Optional[int] = None
    jitter: float = 0.15
    sigma_shrink: float = 0.25
    # observability only -- minted by the outermost layer that sees the
    # request when tracing is enabled; NOT part of the purity tuple (two
    # requests differing only in trace_id produce bitwise the same result)
    trace_id: Optional[str] = None

    def replace(self, **kw: Any) -> "JobRequest":
        return dataclasses.replace(self, **kw)

    def resolved_cfg(self, base_cfg: Any = None) -> Any:
        """The effective algorithm config: the request's own (falling back
        to `base_cfg`), with the `fused` override applied when set."""
        cfg = self.cfg if self.cfg is not None else base_cfg
        if (self.fused is not None and cfg is not None
                and hasattr(cfg, "fused") and cfg.fused != self.fused):
            cfg = dataclasses.replace(cfg, fused=self.fused)
        return cfg


def deprecated_kwargs_request(layer: str, **kw: Any) -> JobRequest:
    """Build a `JobRequest` from a legacy kwarg-form `submit()` call and
    emit the deprecation notice (shared by both shims, so the message and
    the stacklevel stay consistent)."""
    warnings.warn(
        f"{layer}.submit(**kwargs) is deprecated; build a "
        "serve.api.JobRequest and pass it instead (results are "
        "bitwise identical)", DeprecationWarning, stacklevel=3)
    return JobRequest(**kw)


@dataclasses.dataclass(frozen=True)
class ProgressUpdate:
    """One generation-boundary snapshot of a running job, streamed by
    `JobHandle.progress()`.

    `best_objs` is the (wl^2, max bbox) objective vector of the job's
    current champion; `eta_s` extrapolates remaining wallclock from the
    generations already served (None until the first boundary, None again
    whenever extrapolation would be garbage -- see
    `frontend._extrapolate_eta`).  `convergence` is the tail of the job's
    per-step convergence ring -- `(gens, metric)` pairs recorded at step
    boundaries -- so a progress consumer can plot the paper's Fig. 7
    curve live without waiting for the job to finish."""

    jid: int
    status: JobStatus
    gens: int
    budget: int
    metric: float
    best_objs: Any
    eta_s: Optional[float] = None
    convergence: Tuple[Tuple[int, float], ...] = ()


@dataclasses.dataclass(frozen=True)
class JobTrace:
    """`JobHandle.trace()`: everything recorded about one job's journey.

    `events` is the job's slice of the process tracer (empty unless
    tracing was enabled -- `serve.tracing.enabled()`); `convergence` is
    the full `(gens, metric)` history the handle accumulated from
    progress pushes (always on; bounded ring).  `phases` folds the
    begin/end span pairs among the events into `(name, seconds)` tuples.
    """

    trace_id: Optional[str]
    events: Tuple[Any, ...]
    convergence: Tuple[Tuple[int, float], ...]

    @property
    def phases(self) -> List[Tuple[str, float]]:
        from repro.serve import tracing
        return tracing.span_pairs(list(self.events))


class JobHandle:
    """The one job-state surface callers hold, whichever layer serves it.

    Synchronous consumers use `.status`, `.result(timeout=...)`,
    `.exception()` and `.cancel()`; consumers inside the asyncio
    front-end additionally get `await handle.wait()` and
    `async for update in handle.progress()`.  Thread-safe: the serving
    layer resolves the handle from its stepping thread, callers may poll
    from any thread or task.
    """

    # progress buffer depth: a slow consumer sees the freshest updates,
    # never an unbounded backlog
    PROGRESS_BUFFER = 64
    # convergence ring depth: independent of the progress buffer because
    # trace() must see the whole curve even after progress() consumed the
    # updates (the deque is drained by iteration, this ring is not)
    CONVERGENCE_BUFFER = 256

    def __init__(self, jid: int, request: JobRequest) -> None:
        self.jid = jid
        self.request = request
        self._status = JobStatus.QUEUED
        self._result: Any = None           # PlacementJob once DONE
        self._exception: Optional[BaseException] = None
        self._done = threading.Event()
        self._lock = threading.Lock()
        self._progress: collections.deque = collections.deque(
            maxlen=self.PROGRESS_BUFFER)
        self._convergence: collections.deque = collections.deque(
            maxlen=self.CONVERGENCE_BUFFER)
        self._cancel_fn = None             # installed by the serving layer
        # async plumbing (installed by serve.frontend when it owns the
        # handle): loop + event woken on every state/progress change
        self._loop = None
        self._aevent = None

    # ----------------------------------------------------------- reading

    @property
    def status(self) -> JobStatus:
        return self._status

    def result(self, timeout: Optional[float] = None):
        """Block until the job reaches a terminal state and return its
        `PlacementJob` result.  Raises `TimeoutError` on timeout, the
        recorded exception for FAILED, `JobCancelledError` for
        CANCELLED."""
        if not self._done.wait(timeout):
            raise TimeoutError(
                f"job {self.jid} not finished within {timeout}s "
                f"(status={self._status.value})")
        if self._status is JobStatus.CANCELLED:
            raise JobCancelledError(f"job {self.jid} was cancelled")
        if self._exception is not None:
            raise self._exception
        return self._result

    def exception(self, timeout: Optional[float] = None
                  ) -> Optional[BaseException]:
        """Block until terminal; return the job's exception (None for
        DONE / CANCELLED)."""
        if not self._done.wait(timeout):
            raise TimeoutError(
                f"job {self.jid} not finished within {timeout}s")
        return self._exception

    def trace(self) -> JobTrace:
        """Everything recorded about this job: its tracer events (when
        tracing is enabled) and its convergence curve (always).  Valid at
        any point in the lifecycle; after a terminal event it is the
        job's complete history."""
        from repro.serve import tracing
        tid = self.request.trace_id if self.request is not None else None
        events = tuple(tracing.tracer().events(tid)) if tid else ()
        with self._lock:
            conv = tuple(self._convergence)
        return JobTrace(trace_id=tid, events=events, convergence=conv)

    def cancel(self) -> bool:
        """Request cancellation: the slot is freed at the next step
        boundary.  Returns False when the job is already terminal (too
        late), True when the request was accepted.  Terminal state after
        a successful cancel is CANCELLED (observe via `result()` /
        `wait()`)."""
        with self._lock:
            if self._status.terminal:
                return False
            fn = self._cancel_fn
        if fn is None:
            return False
        return bool(fn(self.jid))

    # ------------------------------------------------ async (frontend)

    async def wait(self):
        """Async `result()`: await terminal state, return the result (or
        raise, same contract as `result()`)."""
        if self._aevent is None:
            raise RuntimeError(
                "handle is not attached to an async front-end; use "
                "result(timeout=...) instead")
        while not self._done.is_set():
            await self._aevent.wait()
            self._aevent.clear()
        return self.result()

    async def progress(self):
        """Async iterator of `ProgressUpdate`s, one per step boundary the
        job was alive for, ending when the job reaches a terminal state.
        A slow consumer is never a backpressure source: updates overwrite
        a bounded ring, so it sees the freshest `PROGRESS_BUFFER`."""
        if self._aevent is None:
            raise RuntimeError(
                "progress streaming needs the async front-end "
                "(serve.frontend.PlacementFrontend)")
        while True:
            while True:
                with self._lock:
                    if not self._progress:
                        break
                    update = self._progress.popleft()
                yield update
            if self._done.is_set():
                return
            await self._aevent.wait()
            self._aevent.clear()

    # --------------------------------------- resolution (serving layer)

    def _attach_async(self, loop, aevent) -> None:
        self._loop = loop
        self._aevent = aevent

    def _wake(self) -> None:
        if self._loop is not None and self._aevent is not None:
            try:
                self._loop.call_soon_threadsafe(self._aevent.set)
            except RuntimeError:
                pass                       # loop already closed

    def _mark_running(self) -> None:
        with self._lock:
            if self._status is JobStatus.QUEUED:
                self._status = JobStatus.RUNNING

    def _push_progress(self, update: ProgressUpdate) -> None:
        with self._lock:
            self._progress.append(update)
            # accumulate the convergence curve separately: progress() is a
            # consuming iterator, trace() wants the whole history
            if (not self._convergence
                    or self._convergence[-1][0] != update.gens):
                self._convergence.append((update.gens, update.metric))
        self._wake()

    def _resolve(self, result: Any) -> None:
        with self._lock:
            if self._status.terminal:
                return
            self._status = JobStatus.DONE
            self._result = result
        self._done.set()
        self._wake()

    def _fail(self, exc: BaseException) -> None:
        with self._lock:
            if self._status.terminal:
                return
            self._status = JobStatus.FAILED
            self._exception = exc
        self._done.set()
        self._wake()

    def _cancelled(self) -> None:
        with self._lock:
            if self._status.terminal:
                return
            self._status = JobStatus.CANCELLED
        self._done.set()
        self._wake()

    # ------------------------------------------- deprecated (PR 1-8 API)

    @property
    def done(self) -> bool:
        """Deprecated: use `handle.status is JobStatus.DONE`."""
        warnings.warn("JobHandle.done is deprecated; use handle.status",
                      DeprecationWarning, stacklevel=2)
        return self._status is JobStatus.DONE

    @property
    def failed(self) -> bool:
        """Deprecated: use `handle.status is JobStatus.FAILED`."""
        warnings.warn("JobHandle.failed is deprecated; use handle.status",
                      DeprecationWarning, stacklevel=2)
        return self._status is JobStatus.FAILED

    def __repr__(self) -> str:
        return (f"JobHandle(jid={self.jid}, "
                f"status={self._status.value})")


# ---------------------------------------------------------- stats schemas

class ServiceStats(TypedDict):
    """`PlacementService.stats()`: one pool's serving + compile counters.

    Conventions (shared with `FleetStats` / `FrontendStats`): counters are
    bare nouns (`steps`, `useful_gens`), rates/latencies carry their unit
    suffix (`*_ms`, `*_secs`), booleans read as predicates.  Keys are
    append-only; renames bump `STATS_SCHEMA_VERSION`.
    """

    schema_version: int
    n_slots: int
    gens_per_step: int
    steps: int
    useful_gens: int
    step_compiles: int         # must stay 1 per slot-count ladder size
    sizes: List[int]           # every slot count this pool compiled
    n_islands: int
    migrate_every: int
    jobs_cancelled: int        # slots freed early by cancel()
    blocking_compiles: int
    blocking_compile_secs: float
    prewarm_compiles: int
    prewarm_compile_secs: float
    prewarmed_sizes: List[int]
    time_to_first_gen_ms: Optional[float]
    compiles_total: int
    recompiles_total: int
    compile_secs_total: float
    persistent_cache_dir: Optional[str]
    # --- appended under schema_version 2 (observability) ---
    step_ms_hist: Dict[str, Any]       # Histogram.to_dict() of step wall ms
    convergence: Dict[str, Any]        # jid -> tail of (gens, metric) ring
    tracing_enabled: bool


class FleetStats(TypedDict):
    """`PlacementScheduler.stats()`: fleet-level routing counters plus a
    per-pool map of `ServiceStats` (each augmented with `queue_depth`)."""

    schema_version: int
    n_pools: int
    jobs_submitted: int
    jobs_done: int
    jobs_failed: int
    jobs_cancelled: int
    policy: str
    autoscale_events: List[Tuple[str, int, int]]
    pools: Dict[str, Any]      # label -> ServiceStats + queue_depth
    # --- appended under schema_version 2 (observability) ---
    job_latency_ms_hist: Dict[str, Any]  # submit -> terminal wall ms
    # optional sections (present when the feature is attached):
    #   cache: ChampionStore.stats()      prewarm: Prewarmer.stats()


class FrontendStats(TypedDict):
    """`PlacementFrontend.stats()`: admission/backpressure counters around
    the wrapped scheduler's `FleetStats` (under the `fleet` key)."""

    schema_version: int
    max_queue: int
    submitted: int
    admitted: int
    completed: int
    cancelled: int
    failed: int
    backpressure_waits: int    # submits that had to await a credit
    queue_full_rejections: int  # submit_nowait calls that raised QueueFull
    draining: bool
    fleet: Any                 # FleetStats of the owned scheduler
    # --- appended under schema_version 2 (observability) ---
    job_latency_ms_hist: Dict[str, Any]  # async submit -> terminal wall ms
    tracing_enabled: bool
