"""Structured span/event tracing for the serving stack.

The metrics registry (`runtime.telemetry`) answers *aggregate* questions;
this module answers the per-request one -- "where did job X spend its
400ms" -- with a process-wide, thread-safe event log:

  * every job carries a **trace id** (`JobRequest.trace_id`, minted at the
    outermost layer that sees it) and emits a fixed event taxonomy on its
    way through: ``job.submit`` -> ``job.queued`` -> ``job.admitted``
    (slot/pool attrs) -> exactly one terminal event out of
    ``job.harvested`` / ``job.cancelled`` / ``job.failed`` /
    ``job.cache_hit``;
  * pools emit lifecycle **spans** (begin/end pairs): ``pool.build``,
    ``pool.grow``, ``pool.prewarm_size``, and per-batched-step
    ``pool.step`` windows, plus ``pool.prewarm_adopt`` instants;
  * timestamps are `time.monotonic()` (ordering/duration) with a wall
    clock alongside (correlation across processes).

**Disabled is the default and costs one module-level branch.**  Call
sites guard with ``if tracing.enabled():``; when off, no event object is
ever built.  The bench `telemetry` section hard-gates the disabled-path
overhead (`check_bench.py`).

Exporters (all opt-in):

  * **JSONL sink** -- `enable(jsonl_path=...)` / `REPRO_TRACE_FILE` /
    `launch/serve.py --trace-file`: one JSON object per event, written as
    events happen (the durable form; survives a crash).
  * **Chrome trace** -- `write_chrome_trace(path)`: the in-memory ring
    rendered as Chrome/Perfetto trace-event JSON (``B``/``E`` span pairs,
    ``i`` instants; load in `ui.perfetto.dev` or `chrome://tracing`).
  * **in-memory ring** -- bounded per-trace index backing
    `JobHandle.trace()`; oldest traces evicted FIFO so a long-lived
    process never grows unboundedly.
"""
from __future__ import annotations

import itertools
import json
import os
import threading
import time
from collections import OrderedDict, deque
from dataclasses import dataclass, field
from typing import Any, Dict, IO, List, Optional, Tuple

__all__ = [
    "TraceEvent", "Tracer", "tracer", "enabled", "enable", "disable",
    "maybe_enable_from_env", "new_trace_id", "TERMINAL_EVENTS",
    "JOB_EVENTS", "write_chrome_trace",
]

# one terminal event per job, exactly -- gated by bench + tests
TERMINAL_EVENTS = frozenset(
    {"job.harvested", "job.cancelled", "job.failed", "job.cache_hit"})
JOB_EVENTS = frozenset(
    {"job.submit", "job.queued", "job.admitted"}) | TERMINAL_EVENTS

# ring capacities: ~100 bytes/event in-memory; 64k events / 4k traces
# bounds a long-lived process at a few MB of trace state
MAX_EVENTS = 65536
MAX_TRACES = 4096
MAX_EVENTS_PER_TRACE = 1024

_ENABLED = False
_id_counter = itertools.count(1)


def enabled() -> bool:
    """The single branch every instrumentation site checks."""
    return _ENABLED


def new_trace_id(prefix: str = "job") -> str:
    """Process-unique trace id (monotone counter + pid for cross-process
    uniqueness in JSONL files merged from several workers)."""
    return f"{prefix}-{os.getpid()}-{next(_id_counter)}"


@dataclass(frozen=True)
class TraceEvent:
    """One event: an instant, or one side of a begin/end span pair."""

    name: str
    kind: str                    # "begin" | "end" | "instant"
    ts: float                    # time.monotonic() seconds
    wall: float                  # time.time() seconds
    trace_id: Optional[str] = None
    tid: int = 0                 # emitting thread ident
    attrs: Dict[str, Any] = field(default_factory=dict)

    def to_json(self) -> Dict[str, Any]:
        out: Dict[str, Any] = {"name": self.name, "kind": self.kind,
                               "ts": round(self.ts, 6),
                               "wall": round(self.wall, 6),
                               "tid": self.tid}
        if self.trace_id is not None:
            out["trace"] = self.trace_id
        if self.attrs:
            out["attrs"] = self.attrs
        return out


class Tracer:
    """Thread-safe bounded event log with optional JSONL sinks."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._events: deque = deque(maxlen=MAX_EVENTS)
        self._by_trace: "OrderedDict[str, List[TraceEvent]]" = OrderedDict()
        self._sinks: List[IO[str]] = []
        self._t0 = time.monotonic()

    # ------------------------------------------------------------- record

    def _record(self, ev: TraceEvent) -> None:
        if not _ENABLED:
            # defense in depth: instrumentation sites gate on `enabled()`
            # before even constructing the event; this guard keeps a
            # stray ungated call from recording while tracing is off
            return
        with self._lock:
            self._events.append(ev)
            if ev.trace_id is not None:
                per = self._by_trace.get(ev.trace_id)
                if per is None:
                    while len(self._by_trace) >= MAX_TRACES:
                        self._by_trace.popitem(last=False)
                    per = self._by_trace[ev.trace_id] = []
                if len(per) < MAX_EVENTS_PER_TRACE:
                    per.append(ev)
            sinks = list(self._sinks)
        for f in sinks:
            try:
                f.write(json.dumps(ev.to_json(),
                                   separators=(",", ":")) + "\n")
                f.flush()
            except (OSError, ValueError):
                pass                       # a dead sink never kills serving

    def instant(self, name: str, trace_id: Optional[str] = None,
                **attrs: Any) -> None:
        self._record(TraceEvent(name=name, kind="instant",
                                ts=time.monotonic(), wall=time.time(),
                                trace_id=trace_id,
                                tid=threading.get_ident(), attrs=attrs))

    def begin(self, name: str, trace_id: Optional[str] = None,
              **attrs: Any) -> None:
        self._record(TraceEvent(name=name, kind="begin",
                                ts=time.monotonic(), wall=time.time(),
                                trace_id=trace_id,
                                tid=threading.get_ident(), attrs=attrs))

    def end(self, name: str, trace_id: Optional[str] = None,
            **attrs: Any) -> None:
        self._record(TraceEvent(name=name, kind="end",
                                ts=time.monotonic(), wall=time.time(),
                                trace_id=trace_id,
                                tid=threading.get_ident(), attrs=attrs))

    class _Span:
        __slots__ = ("_tracer", "_name", "_trace_id", "_attrs")

        def __init__(self, tracer: "Tracer", name: str,
                     trace_id: Optional[str], attrs: Dict[str, Any]):
            self._tracer = tracer
            self._name = name
            self._trace_id = trace_id
            self._attrs = attrs

        def __enter__(self) -> "Tracer._Span":
            self._tracer.begin(self._name, self._trace_id, **self._attrs)
            return self

        def __exit__(self, exc_type, exc, tb) -> None:
            attrs = dict(self._attrs)
            if exc_type is not None:
                attrs["error"] = exc_type.__name__
            self._tracer.end(self._name, self._trace_id, **attrs)

    def span(self, name: str, trace_id: Optional[str] = None,
             **attrs: Any) -> "Tracer._Span":
        """``with tracer().span("pool.step", pool=label): ...``"""
        return Tracer._Span(self, name, trace_id, attrs)

    # -------------------------------------------------------------- query

    def events(self, trace_id: Optional[str] = None) -> List[TraceEvent]:
        with self._lock:
            if trace_id is not None:
                return list(self._by_trace.get(trace_id, ()))
            return list(self._events)

    def clear(self) -> None:
        with self._lock:
            self._events.clear()
            self._by_trace.clear()

    # -------------------------------------------------------------- sinks

    def add_jsonl_sink(self, path: str) -> None:
        f = open(path, "a", encoding="utf-8")
        with self._lock:
            self._sinks.append(f)

    def close_sinks(self) -> None:
        with self._lock:
            sinks, self._sinks = self._sinks, []
        for f in sinks:
            try:
                f.close()
            except OSError:
                pass

    # ------------------------------------------------------- chrome trace

    def chrome_trace(self) -> Dict[str, Any]:
        """The in-memory ring as a Chrome/Perfetto trace-event dict.

        Spans map to ``B``/``E`` phase pairs, instants to ``i``; ts is
        microseconds relative to tracer start; each trace id becomes an
        ``args.trace`` attribute so Perfetto's query view can group by
        job."""
        pid = os.getpid()
        phase = {"begin": "B", "end": "E", "instant": "i"}
        events = []
        for ev in self.events():
            out: Dict[str, Any] = {
                "name": ev.name,
                "ph": phase[ev.kind],
                "ts": (ev.ts - self._t0) * 1e6,
                "pid": pid,
                "tid": ev.tid,
            }
            args = dict(ev.attrs)
            if ev.trace_id is not None:
                args["trace"] = ev.trace_id
            if args:
                out["args"] = args
            if ev.kind == "instant":
                out["s"] = "t"             # thread-scoped instant
            events.append(out)
        return {"traceEvents": events, "displayTimeUnit": "ms"}

    def write_chrome_trace(self, path: str) -> None:
        with open(path, "w", encoding="utf-8") as f:
            json.dump(self.chrome_trace(), f)


_TRACER = Tracer()


def tracer() -> Tracer:
    """The process-global tracer (valid even while tracing is disabled --
    `enabled()` is what instrumentation sites gate on)."""
    return _TRACER


def enable(jsonl_path: Optional[str] = None) -> None:
    """Turn tracing on; optionally attach a JSONL sink."""
    global _ENABLED
    if jsonl_path:
        _TRACER.add_jsonl_sink(jsonl_path)
    _ENABLED = True


def disable(close_sinks: bool = True) -> None:
    global _ENABLED
    _ENABLED = False
    if close_sinks:
        _TRACER.close_sinks()


def maybe_enable_from_env(trace_file: Optional[str] = None) -> bool:
    """Enable tracing if `trace_file` or `$REPRO_TRACE_FILE` names a sink,
    or if `$REPRO_TELEMETRY` is a truthy flag (tracing without a file:
    in-memory ring + `JobHandle.trace()` only).  Returns enabled state."""
    path = trace_file or os.environ.get("REPRO_TRACE_FILE") or None
    flag = os.environ.get("REPRO_TELEMETRY", "").strip().lower()
    if path:
        enable(path)
    elif flag in ("1", "true", "on", "yes"):
        enable()
    return _ENABLED


def write_chrome_trace(path: str) -> None:
    """Module-level convenience over the global tracer."""
    _TRACER.write_chrome_trace(path)


def span_pairs(events: List[TraceEvent]) -> List[Tuple[str, float]]:
    """Fold begin/end pairs into (name, duration_s) tuples -- the
    ingredient for per-phase timing summaries in tests and tools."""
    open_spans: Dict[Tuple[str, int], float] = {}
    out: List[Tuple[str, float]] = []
    for ev in events:
        key = (ev.name, ev.tid)
        if ev.kind == "begin":
            open_spans[key] = ev.ts
        elif ev.kind == "end" and key in open_spans:
            out.append((ev.name, ev.ts - open_spans.pop(key)))
    return out
