"""Parse compiled HLO text for roofline inputs (per-device program walk).

XLA's `cost_analysis()` counts each `while` body ONCE -- a scanned 32-layer
model reports ~1/32 of its real FLOPs.  This parser walks `as_text()` and:

  1. splits the module into named computations,
  2. recovers `while` trip counts from the loop condition's
     compare-against-constant (the lax.scan lowering pattern),
  3. propagates multipliers through the call graph (while bodies, fusions,
     to_apply reducers, conditionals),
  4. accumulates, multiplier-weighted:
       * collective bytes by kind (all-reduce / all-gather / reduce-scatter
         / all-to-all / collective-permute, incl. async -start forms),
         sized by output shape,
       * dot FLOPs (2 x |out| x |contraction|), counted inside fusions too,
       * HBM traffic proxy: sum of operand+output bytes of every op at
         non-fused level (fusion interiors live in registers/VMEM).

All sizes are PER DEVICE (the compiled module is the per-device program).
Validated against closed-form counts in tests/test_hloparse.py.
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, List, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"\b(\w+)\[([\d,]*)\]")


def _shape_list(seg: str) -> List[Tuple[str, int, Tuple[int, ...]]]:
    """All typed shapes in a segment -> [(dtype, bytes, dims)]."""
    out = []
    for m in _SHAPE_RE.finditer(seg):
        dt, dims_s = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        dims = tuple(int(d) for d in dims_s.split(",")) if dims_s else ()
        n = 1
        for d in dims:
            n *= d
        out.append((dt, n * _DTYPE_BYTES[dt], dims))
    return out


def _split_computations(text: str) -> Dict[str, List[str]]:
    comps: Dict[str, List[str]] = {}
    order: List[str] = []
    cur = None
    for line in text.splitlines():
        stripped = line.strip()
        m = re.match(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->", stripped)
        if m and stripped.endswith("{"):
            cur = m.group(2)
            comps[cur] = []
            order.append(cur)
        elif stripped == "}" or stripped.startswith("} "):
            cur = None
        elif cur is not None:
            comps[cur].append(stripped)
    return comps


def _trip_count(cond_lines: List[str]) -> int:
    best = 1
    for ln in cond_lines:
        for m in re.finditer(r"constant\((\d+)\)", ln):
            best = max(best, int(m.group(1)))
    return best


def _call_edges(comps: Dict[str, List[str]]):
    """(caller -> [(callee, multiplier)]), fusion-called set."""
    children: Dict[str, List[Tuple[str, int]]] = defaultdict(list)
    fused: set = set()
    for name, lines in comps.items():
        for ln in lines:
            wm = re.search(r"while\(.*?condition=%?([\w\.\-]+),\s*"
                           r"body=%?([\w\.\-]+)", ln)
            if wm:
                cond, body = wm.group(1), wm.group(2)
                trips = _trip_count(comps.get(cond, []))
                children[name].append((body, trips))
                children[name].append((cond, trips))
                continue
            is_fusion = re.search(r"\bfusion\(", ln) is not None
            for attr in ("calls=", "to_apply=", "body=", "condition=",
                         "branch_computations={", "true_computation=",
                         "false_computation="):
                if attr in ln:
                    seg = ln.split(attr, 1)[1]
                    m = re.match(r"[{%]*([\w\.\-]+)", seg)
                    if m and m.group(1) in comps:
                        children[name].append((m.group(1), 1))
                        if is_fusion:
                            fused.add(m.group(1))
    return children, fused


def _multipliers(comps, children) -> Dict[str, float]:
    called = {c for kids in children.values() for c, _ in kids}
    roots = [n for n in comps if n not in called]
    mult: Dict[str, float] = defaultdict(float)

    def visit(name: str, m: float, depth: int = 0):
        if depth > 64:
            return
        mult[name] += m
        for child, k in children.get(name, []):
            visit(child, m * k, depth + 1)

    for r in roots:
        visit(r, 1.0)
    return mult


_DEF_RE = re.compile(r"^%?([\w\.\-]+)\s*=\s*(.*)$")
_OPKIND_RE = re.compile(r"^(?:\([^=]*\)|[\w\[\],{}]+)\s+([\w\-]+)\(")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")

# plumbing ops that move no HBM bytes of their own
_NO_TRAFFIC = {"tuple", "get-tuple-element", "parameter", "constant",
               "while", "conditional", "call", "bitcast", "bitcast-convert",
               "after-all", "add-dependency", "opt-barrier", "domain",
               "partition-id", "replica-id", "iota"}


def _parse_line(ln: str):
    """-> (name, out_shapes, op_kind, rest) or None."""
    if ln.startswith("ROOT "):
        ln = ln[5:]
    if "/*" in ln:
        ln = re.sub(r"/\*.*?\*/", "", ln)
    m = _DEF_RE.match(ln)
    if not m:
        return None
    name, rhs = m.group(1), m.group(2)
    km = _OPKIND_RE.match(rhs)
    kind = km.group(1) if km else ""
    paren = rhs.find("(")
    type_seg = rhs[:paren] if paren > 0 else rhs
    # strip the op-kind word itself from the type segment
    if km:
        type_seg = type_seg.rsplit(km.group(1), 1)[0]
    return name, _shape_list(type_seg), kind, rhs


def _operand_segment(rhs: str) -> str:
    """The text inside the op's argument parens (first balanced group)."""
    start = rhs.find("(")
    if start < 0:
        return ""
    depth = 0
    for i in range(start, len(rhs)):
        if rhs[i] == "(":
            depth += 1
        elif rhs[i] == ")":
            depth -= 1
            if depth == 0:
                return rhs[start + 1:i]
    return rhs[start + 1:]


def analyze(text: str) -> Dict[str, float]:
    """Trip-count-aware per-device totals: flops, traffic, collectives."""
    comps = _split_computations(text)
    children, fused = _call_edges(comps)
    mult = _multipliers(comps, children)

    coll = {k: 0.0 for k in COLLECTIVES}
    flops = 0.0
    traffic = 0.0
    for name, lines in comps.items():
        m = mult.get(name, 1.0)
        in_fusion = name in fused
        # symbol table: value name -> (bytes, dims of first shape, kind)
        sym: Dict[str, Tuple[int, Tuple[int, ...], str]] = {}
        parsed = []
        root = None
        for ln in lines:
            is_root = ln.startswith("ROOT ")
            p = _parse_line(ln)
            if p is None:
                continue
            nm, shapes, kind, rhs = p
            sym[nm] = (sum(b for _, b, _ in shapes),
                       shapes[0][2] if shapes else (), kind)
            parsed.append(p)
            if is_root:
                root = nm

        # consumers per value (for fusion-interior slice accounting)
        consumers: Dict[str, List[str]] = defaultdict(list)
        for nm, shapes, kind, rhs in parsed:
            for o in _OPERAND_RE.findall(_operand_segment(rhs)):
                if o in sym:
                    consumers[o].append(nm)

        def _sliced_read(nm: str) -> float:
            """Bytes actually read from value nm given its consumers."""
            cons = consumers.get(nm, [])
            if cons and all(sym[c][2] in ("dynamic-slice", "gather", "slice")
                            for c in cons):
                return float(sum(sym[c][0] for c in cons))
            return float(sym[nm][0])

        for nm, shapes, kind, rhs in parsed:
            out_bytes = sum(b for _, b, _ in shapes)
            operands = [o for o in _OPERAND_RE.findall(_operand_segment(rhs))
                        if o in sym]
            if kind == "dot":
                n_out = 1
                for d in (shapes[0][2] if shapes else ()):
                    n_out *= d
                contract = 1
                cm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", rhs)
                if cm and operands:
                    lhs_dims = sym[operands[0]][1]
                    for idx in cm.group(1).split(","):
                        if idx and int(idx) < len(lhs_dims):
                            contract *= lhs_dims[int(idx)]
                flops += 2.0 * n_out * contract * m

            for ck in COLLECTIVES:
                if kind.startswith(ck):
                    coll[ck] += out_bytes * m
                    break

            if in_fusion:
                # interior reads: parameters, sized by how they're consumed
                if kind == "parameter":
                    traffic += _sliced_read(nm) * m
                # interior write: only the root leaves the fusion
                if nm == root:
                    if kind == "dynamic-update-slice" and len(operands) > 1:
                        traffic += sym[operands[1]][0] * m
                    elif kind == "tuple":
                        for o in operands:
                            if sym[o][2] == "dynamic-update-slice":
                                traffic += 0  # sized via its own update
                            else:
                                traffic += sym[o][0] * m
                    else:
                        traffic += out_bytes * m
                elif kind == "dynamic-update-slice":
                    # DUS feeding the root tuple: in-place update window
                    traffic += (sym[operands[1]][0] * m
                                if len(operands) > 1 else 0.0)
            elif kind == "fusion":
                pass   # accounted inside the fused computation
            elif kind not in _NO_TRAFFIC and not kind.endswith("-done"):
                if kind in ("dynamic-slice", "gather", "slice"):
                    traffic += 2.0 * out_bytes * m
                elif kind in ("dynamic-update-slice", "scatter"):
                    upd = (sym[operands[1]][0]
                           if len(operands) > 1 else out_bytes)
                    traffic += 2.0 * upd * m
                else:
                    traffic += (out_bytes
                                + sum(_sliced_read(o) for o in operands)) * m
    total = sum(coll.values())
    return dict(coll, total=total, flops=flops, traffic_bytes=traffic)


def collective_bytes(text: str) -> Dict[str, float]:
    a = analyze(text)
    return {k: a[k] for k in COLLECTIVES + ("total",)}
