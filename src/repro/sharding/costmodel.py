"""Analytical roofline cost model: the autoshard fitness function.

The paper's speed came from replacing slow Vivado invocations with a fast
analytical wirelength estimator (SS I wish-list item 3).  The TPU analogue:
instead of `.lower().compile()` per sharding candidate (minutes), estimate
the three roofline terms in microseconds from closed-form byte/FLOP counts.
The Pareto winner is then *verified* with one real compile (launch/dryrun).

Terms per train/serve step, for an (arch, shape, mesh, rules) candidate:

  compute_s    = step FLOPs / (chips * PEAK_FLOPS)
  memory_s     = per-device HBM traffic / HBM_BW
                 (params read + activations r/w + KV traffic)
  collective_s = per-device collective bytes / ICI_BW, summing
                 - DP gradient all-reduce      2 * P_sharded * (n-1)/n
                 - TP activation all-reduces    2 per layer matmul pair
                 - EP combine psums             token bytes per MoE layer
                 - vocab logits reductions      LSE partials

Hardware constants are the v5e numbers given in the assignment.
All formulas are documented inline; tests pin them against hand-computed
small cases, and EXPERIMENTS.md SSRoofline cross-checks the model against
the compiled dry-run's cost_analysis.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np

from repro.configs.base import SHAPES, ShapeSpec
from repro.models.transformer import ArchConfig

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link
BYTES = 2                    # bf16


@dataclasses.dataclass(frozen=True)
class MeshShape:
    pod: int
    data: int
    model: int

    @property
    def chips(self) -> int:
        return self.pod * self.data * self.model

    def size(self, axes) -> int:
        if axes is None:
            return 1
        if isinstance(axes, str):
            axes = (axes,)
        out = 1
        for a in axes:
            out *= {"pod": self.pod, "data": self.data,
                    "model": self.model}[a]
        return out


@dataclasses.dataclass(frozen=True)
class CostReport:
    compute_s: float
    memory_s: float
    collective_s: float
    bytes_per_device: float
    model_flops: float

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_s(self) -> float:
        # optimistic overlap: max of the three terms (roofline bound)
        return max(self.compute_s, self.memory_s, self.collective_s)


def model_flops_per_step(cfg: ArchConfig, shape: ShapeSpec) -> float:
    """6 * N_active * D for train, 2 * N_active * D for inference."""
    n_active = _active_params(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    tokens = shape.global_batch        # decode: one token per sequence
    return 2.0 * n_active * tokens


def _active_params(cfg: ArchConfig) -> float:
    """Per-token active parameters (MoE: top_k + shared only)."""
    total = cfg.param_count()
    if not cfg.moe_every:
        return float(total)
    # replace full expert banks by the activated fraction
    e = max(cfg.n_padded, cfg.n_routed)
    expert_p = 3 * cfg.d_model * cfg.d_expert
    n_moe_layers = cfg.n_layers // cfg.moe_every
    routed_all = n_moe_layers * e * expert_p
    routed_active = n_moe_layers * cfg.top_k * expert_p
    return float(total - routed_all + routed_active)


def _param_bytes(cfg: ArchConfig) -> float:
    return cfg.param_count() * BYTES


def estimate(cfg: ArchConfig, shape_name: str, mesh: MeshShape,
             rules: Optional[Dict[str, object]] = None) -> CostReport:
    """Three-term roofline estimate for one (arch, shape, mesh, rules)."""
    shape = SHAPES[shape_name]
    rules = rules or {}
    batch_ax = rules.get("batch", ("pod", "data"))
    model_ax = rules.get("model_dim", "model")    # width sharding axis
    kvseq_ax = rules.get("kv_seq", "model")
    # axis-claim ordering mirrors logical.spec_for: an axis spent on the
    # batch cannot also shard weights -- v1 of this model ignored that and
    # the EA promptly exploited it (claimed 0.22 GiB/device layouts), the
    # exact estimator-misleads-optimizer failure the paper reports for
    # wirelength-only objectives (SS III-A); see EXPERIMENTS.md SSPerf.
    def _axes_tuple(ax):
        if ax is None:
            return ()
        return (ax,) if isinstance(ax, str) else tuple(ax)

    claimed = set(_axes_tuple(batch_ax))
    tp_axes = tuple(a for a in _axes_tuple(model_ax) if a not in claimed)
    claimed |= set(tp_axes)
    dp = mesh.size(batch_ax)
    tp = mesh.size(tp_axes) if tp_axes else 1
    # width dims must actually divide; else weights replicate
    if tp > 1 and (cfg.d_ff % tp or (cfg.moe_every and
                                     max(cfg.n_padded, cfg.n_routed) % tp)):
        tp = 1
    chips = mesh.chips

    flops = model_flops_per_step(cfg, shape)
    compute_s = flops / (chips * PEAK_FLOPS)

    b, s = shape.global_batch, shape.seq_len
    d = cfg.d_model
    L = cfg.n_layers

    # ---- per-device memory traffic
    p_bytes = _param_bytes(cfg) / max(tp, 1)       # weights read once/step
    if shape.kind == "train":
        tok_loc = b * s / max(dp, 1)
        act_rw = 12 * tok_loc * d * BYTES * L / max(tp, 1)  # r+w main tensors
        p_traffic = 3 * p_bytes                     # fwd read, bwd read, upd
    elif shape.kind == "prefill":
        tok_loc = b * s / max(dp, 1)
        act_rw = 6 * tok_loc * d * BYTES * L / max(tp, 1)
        p_traffic = p_bytes
    else:  # decode: KV cache scan dominates
        kv_heads_bytes = (2 * cfg.n_kv_heads * cfg.d_head * BYTES
                          if not cfg.rwkv else 0)
        n_attn = _n_attn_layers(cfg)
        kv_total = b * s * kv_heads_bytes * n_attn
        act_rw = kv_total / (max(dp, 1) * mesh.size(kvseq_ax)) \
            if kv_heads_bytes else 0.0
        # ssm/rwkv state traffic
        if cfg.rwkv or cfg.attn_every:
            n_ssm = L - n_attn
            state = b * (d // 64) * 64 * 64 * 4 if cfg.rwkv \
                else b * 2 * d * cfg.d_state * 4
            act_rw += 2 * state * n_ssm / max(dp, 1)
        p_traffic = p_bytes
    memory_s = (p_traffic + act_rw) / HBM_BW

    # ---- collective bytes per device
    coll = 0.0
    if shape.kind == "train" and dp > 1:
        grad_bytes = _param_bytes(cfg) / max(tp, 1)
        coll += 2.0 * grad_bytes * (dp - 1) / dp          # ring all-reduce
    if tp > 1:
        tok_loc = (b * s if shape.kind != "decode" else b) / max(dp, 1)
        # 2 all-reduces (attn out + mlp out) per layer, activation-sized
        per_layer = 2.0 * tok_loc * d * BYTES * (tp - 1) / tp
        mult = 2.0 if shape.kind == "train" else 1.0      # bwd doubles it
        coll += per_layer * L * mult
        # vocab-sharded logits LSE partials
        if shape.kind == "train":
            coll += 2.0 * tok_loc * 4 * (tp - 1)
    if cfg.moe_every and tp > 1 and shape.kind != "decode":
        tok_loc = b * s / max(dp, 1)
        n_moe = cfg.n_layers // cfg.moe_every
        mult = 2.0 if shape.kind == "train" else 1.0
        coll += tok_loc * d * BYTES * n_moe * mult * (tp - 1) / tp  # EP psum
    if shape.kind == "decode" and mesh.size(kvseq_ax) > 1:
        n_attn = _n_attn_layers(cfg)
        coll += b * cfg.n_heads * (cfg.d_head + 2) * 4 * n_attn \
            * (mesh.size(kvseq_ax) - 1) / mesh.size(kvseq_ax)
    collective_s = coll / ICI_BW

    # ---- per-device residency (the bbox analogue): params+opt+act+cache
    # fsdp may only spend axes not already claimed by batch/width
    fsdp_axes = tuple(a for a in _axes_tuple(rules.get("fsdp", None))
                      if a not in claimed)
    fsdp = mesh.size(fsdp_axes) if fsdp_axes else 1
    res = _param_bytes(cfg) / max(tp, 1)
    if shape.kind == "train":
        res = res / max(fsdp, 1)
        res += 3 * 4 * cfg.param_count() / (max(tp, 1) * max(fsdp, 1))
        res += 2 * (b * s / max(dp, 1)) * d * BYTES * np.sqrt(L)  # remat live
    elif shape.kind == "decode":
        n_attn = _n_attn_layers(cfg)
        kv = (b * s * 2 * cfg.n_kv_heads * cfg.d_head * BYTES * n_attn
              if not cfg.rwkv else 0)
        res += kv / (max(dp, 1) * mesh.size(kvseq_ax))
    else:
        res += (b * s / max(dp, 1)) * d * BYTES * 4

    return CostReport(compute_s=compute_s, memory_s=memory_s,
                      collective_s=collective_s, bytes_per_device=res,
                      model_flops=flops)


def _n_attn_layers(cfg: ArchConfig) -> int:
    if cfg.rwkv:
        return 0
    if cfg.attn_every:
        return cfg.n_layers // cfg.attn_every
    return cfg.n_layers
