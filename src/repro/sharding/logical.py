"""Logical-axis sharding rules with divisibility-aware fallback.

Models annotate every parameter and activation with *logical* axis names
("embed", "q_flat", "experts", "batch", ...).  A `Rules` table maps logical
names to mesh axes; `spec_for` resolves a logical signature to a concrete
`PartitionSpec`, silently dropping any assignment whose mesh-axis product
does not divide the dimension (the legality constraint -- the TPU analogue
of the paper's cascade constraint Eq. 5, see DESIGN.md SS2).

The rules table is exactly the *sharding genotype* that `core.autoshard`
evolves: a placement of tensor dimensions onto mesh axes, scored by the
roofline cost model.

Usage:
    with activate(mesh, rules):
        lowered = jax.jit(train_step, in_shardings=...).lower(...)
Inside model code: `x = constrain(x, "batch", "seq", None)` etc.
Without an active context every call is the identity, so the same model
runs unmodified on a single CPU device (smoke tests).
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

MeshAxes = Union[None, str, Tuple[str, ...]]


@dataclasses.dataclass(frozen=True)
class Rules:
    """logical axis name -> mesh axis (or tuple of axes, or None)."""

    table: Tuple[Tuple[str, MeshAxes], ...]

    def get(self, name: str) -> MeshAxes:
        for k, v in self.table:
            if k == name:
                return v
        return None

    def override(self, **kv: MeshAxes) -> "Rules":
        items = [(k, v) for k, v in self.table if k not in kv]
        items += list(kv.items())
        return Rules(tuple(items))

    def as_dict(self) -> Dict[str, MeshAxes]:
        return dict(self.table)


def default_rules(multi_pod: bool = False) -> Rules:
    """The baseline layout: batch over (pod,)data; width over model."""
    batch = ("pod", "data") if multi_pod else ("data",)
    return Rules((
        ("batch", batch),
        ("seq", None),                 # sequence replicated by default
        ("kv_seq", "model"),           # KV caches: flash-decoding split-KV
        ("embed", None),
        ("q_flat", "model"),           # flattened H*dh -- divides everywhere
        ("kv_flat", "model"),
        ("heads", "model"),
        ("kv_heads", "model"),
        ("head", None),
        ("mlp", "model"),
        ("experts", "model"),
        ("expert_mlp", None),
        ("vocab", "model"),
        ("ssm_inner", "model"),
        ("ssm_state", None),
        ("frontend", None),
    ))


# --------------------------------------------------------------- context

_ACTIVE: List[Tuple[Mesh, Rules]] = []


@contextlib.contextmanager
def activate(mesh: Mesh, rules: Rules):
    _ACTIVE.append((mesh, rules))
    try:
        with mesh:
            yield
    finally:
        _ACTIVE.pop()


def current() -> Optional[Tuple[Mesh, Rules]]:
    return _ACTIVE[-1] if _ACTIVE else None


def current_mesh() -> Optional[Mesh]:
    c = current()
    return c[0] if c else None


# ------------------------------------------------------------- resolution

def _axes_size(mesh: Mesh, axes: MeshAxes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    return int(np.prod([mesh.shape[a] for a in axes]))


def spec_for(axes: Sequence[Optional[str]], shape: Sequence[int],
             mesh: Optional[Mesh] = None,
             rules: Optional[Rules] = None) -> P:
    """Resolve logical axes -> PartitionSpec with divisibility fallback."""
    ctx = current()
    if mesh is None or rules is None:
        if ctx is None:
            return P(*([None] * len(shape)))
        mesh = mesh or ctx[0]
        rules = rules or ctx[1]
    parts: List[MeshAxes] = []
    used: set = set()
    for name, dim in zip(axes, shape):
        assign = rules.get(name) if name else None
        if assign is not None:
            tup = (assign,) if isinstance(assign, str) else tuple(assign)
            tup = tuple(a for a in tup if a in mesh.shape and a not in used)
            size = _axes_size(mesh, tup)
            if size > 1 and dim % size == 0:
                parts.append(tup if len(tup) > 1 else tup[0])
                used.update(tup)
                continue
        parts.append(None)
    return P(*parts)


def constrain(x: jax.Array, *axes: Optional[str]) -> jax.Array:
    """with_sharding_constraint via logical axes; identity w/o context."""
    ctx = current()
    if ctx is None:
        return x
    mesh, rules = ctx
    spec = spec_for(axes, x.shape, mesh, rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def named_sharding(axes: Sequence[Optional[str]], shape: Sequence[int],
                   mesh: Optional[Mesh] = None,
                   rules: Optional[Rules] = None) -> NamedSharding:
    ctx = current()
    mesh = mesh or (ctx[0] if ctx else None)
    rules = rules or (ctx[1] if ctx else None)
    assert mesh is not None, "no active mesh"
    return NamedSharding(mesh, spec_for(axes, shape, mesh, rules))


def tree_shardings(spec_tree, shape_tree, mesh: Optional[Mesh] = None,
                   rules: Optional[Rules] = None):
    """Map a tree of logical-axis tuples + shapes -> NamedShardings."""
    return jax.tree.map(
        lambda axes, shp: named_sharding(axes, shp, mesh, rules),
        spec_tree, shape_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x))
