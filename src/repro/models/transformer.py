"""Architecture builder: dense / MoE / hybrid / SSM stacks from one config.

A model is a periodic pattern of blocks (gemma3: 5 local + 1 global
attention; jamba: 7 mamba + 1 attention with MoE on alternate layers;
deepseek/qwen: MoE every layer; rwkv: attention-free).  Parameters for each
pattern position are stacked across periods so the layer stack lowers as a
single `lax.scan` -- essential to keep HLO size and compile time flat in
depth for the 88-layer dry-run configs.

Exposes the three lowering entry points of the framework:
  * `loss_fn` / train     -- full causal LM loss (+ MoE aux),
  * `prefill`             -- logits for the last position + per-layer caches,
  * `decode_step`         -- one token against carried caches/states.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention, mamba, mlp, modules as nn, moe, rwkv
from repro.sharding import logical


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                    # dense | moe | hybrid | ssm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab: int
    rope_theta: float = 10_000.0
    # attention pattern
    window: Optional[int] = None   # sliding-window width for local layers
    local_ratio: int = 0           # N local layers per 1 global (gemma3: 5)
    # MoE
    moe_every: int = 0             # 0: none, 1: every layer, 2: alternate
    n_routed: int = 0
    top_k: int = 0
    n_shared: int = 0
    d_expert: int = 0
    n_padded: int = 0
    # hybrid (jamba)
    attn_every: int = 0            # one attention layer per this many
    d_state: int = 16
    # ssm
    rwkv: bool = False
    # modality frontend (stub: precomputed embeddings)
    frontend: Optional[str] = None
    n_frontend_tokens: int = 0
    subquadratic: bool = False     # may run long_500k
    norm_eps: float = 1e-6

    # ------------------------------------------------------------ pattern

    @property
    def period(self) -> int:
        p = 1
        if self.local_ratio:
            p = self.local_ratio + 1
        if self.attn_every:
            p = max(p, self.attn_every)
        if self.moe_every:
            p = max(p, self.moe_every)
        assert self.n_layers % p == 0, (self.n_layers, p)
        return p

    @property
    def n_periods(self) -> int:
        return self.n_layers // self.period

    def layer_kind(self, pos: int) -> Dict[str, Any]:
        """Block descriptor for pattern position `pos` (0..period-1)."""
        if self.rwkv:
            return {"mixer": "rwkv", "ffn": None}
        if self.attn_every:
            mixer = "attn" if pos == self.attn_every // 2 else "mamba"
        elif self.local_ratio:
            mixer = "attn_local" if pos < self.local_ratio else "attn"
        else:
            mixer = "attn_local" if self.window else "attn"
        if self.moe_every and (pos % self.moe_every == self.moe_every - 1):
            ffn = "moe"
        elif self.moe_every == 1:
            ffn = "moe"
        else:
            ffn = "mlp"
        return {"mixer": mixer, "ffn": ffn}

    # ------------------------------------------------------------ helpers

    def attn_args(self, local: bool) -> attention.AttnArgs:
        import os as _os
        pq = pkv = 0
        if _os.environ.get("REPRO_PAD_HEADS") == "1":
            # SSPerf lever: round head counts up to divide the model axis;
            # padded heads are hard-masked (model function unchanged)
            if self.n_heads % 16:
                pq = -(-self.n_heads // 16) * 16
            if self.n_kv_heads % 16 and self.n_kv_heads >= 8:
                pkv = 16
        return attention.AttnArgs(
            d_model=self.d_model, n_heads=self.n_heads,
            n_kv_heads=self.n_kv_heads, d_head=self.d_head,
            rope_theta=self.rope_theta,
            window=self.window if local else None,
            pad_q_heads=pq, pad_kv_heads=pkv)

    def moe_args(self) -> moe.MoEArgs:
        return moe.MoEArgs(
            d_model=self.d_model, n_routed=self.n_routed, top_k=self.top_k,
            d_expert=self.d_expert, n_shared=self.n_shared,
            n_padded=self.n_padded)

    def mamba_args(self) -> mamba.MambaArgs:
        return mamba.MambaArgs(d_model=self.d_model, d_state=self.d_state)

    def rwkv_args(self) -> rwkv.RWKVArgs:
        return rwkv.RWKVArgs(d_model=self.d_model, d_ff=self.d_ff)

    def param_count(self) -> int:
        specs = model_specs(self)
        blocks = sum(nn.param_count(specs["blocks"][pos])
                     for pos in range(self.period)) * self.n_periods
        other = nn.param_count({k: v for k, v in specs.items()
                                if k != "blocks"})
        return blocks + other


# ------------------------------------------------------------------ specs

def _block_specs(cfg: ArchConfig, pos: int):
    kind = cfg.layer_kind(pos)
    s: Dict[str, Any] = {}
    if kind["mixer"] == "rwkv":
        s["rwkv"] = rwkv.specs(cfg.rwkv_args())
        return s
    s["ln1"] = nn.ParamSpec((cfg.d_model,), ("embed",), "ones")
    if kind["mixer"] == "mamba":
        s["mamba"] = mamba.specs(cfg.mamba_args())
    else:
        s["attn"] = attention.specs(
            cfg.attn_args(kind["mixer"] == "attn_local"))
    s["ln2"] = nn.ParamSpec((cfg.d_model,), ("embed",), "ones")
    if kind["ffn"] == "moe":
        s["moe"] = moe.specs(cfg.moe_args())
    else:
        s["mlp"] = mlp.specs(cfg.d_model, cfg.d_ff)
    return s


def model_specs(cfg: ArchConfig):
    return {
        "embed": nn.ParamSpec((cfg.vocab, cfg.d_model), ("vocab", "embed"),
                              "normal", 0.02),
        "blocks": [_block_specs(cfg, pos) for pos in range(cfg.period)],
        "ln_f": nn.ParamSpec((cfg.d_model,), ("embed",), "ones"),
        "head": nn.dense_spec(cfg.d_model, cfg.vocab, ("embed", "vocab"),
                              scale=0.02),
    }


def init_params(cfg: ArchConfig, key: jax.Array, dtype=jnp.float32):
    """Realise params; per-pattern-position leaves stacked over periods."""
    specs = model_specs(cfg)
    k_embed, k_blocks, k_out = jax.random.split(key, 3)
    params = {
        "embed": nn.init_tree(specs["embed"], k_embed, dtype),
        "ln_f": nn.init_tree(specs["ln_f"], k_out, dtype),
        "head": nn.init_tree(specs["head"],
                             jax.random.fold_in(k_out, 1), dtype),
        "blocks": [],
    }
    for pos in range(cfg.period):
        bs = specs["blocks"][pos]
        stacked = jax.vmap(
            lambda k: nn.init_tree(bs, k, dtype))(
            jax.random.split(jax.random.fold_in(k_blocks, pos),
                             cfg.n_periods))
        params["blocks"].append(stacked)
    return params


def param_axes(cfg: ArchConfig):
    """Logical axes matching init_params (stacked leaves get leading None)."""
    specs = model_specs(cfg)
    axes = {
        "embed": specs["embed"].axes,
        "ln_f": specs["ln_f"].axes,
        "head": specs["head"].axes,
        "blocks": [jax.tree.map(lambda s: (None,) + s.axes,
                                specs["blocks"][pos],
                                is_leaf=lambda x: isinstance(x, nn.ParamSpec))
                   for pos in range(cfg.period)],
    }
    return axes


# ---------------------------------------------------------------- forward

def _apply_block(cfg: ArchConfig, pos: int, p, x: jnp.ndarray
                 ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Full-sequence block (train).  Returns (x, aux)."""
    kind = cfg.layer_kind(pos)
    aux = jnp.float32(0.0)
    if kind["mixer"] == "rwkv":
        state = rwkv.init_state(cfg.rwkv_args(), x.shape[0])
        x, _ = rwkv.apply(p["rwkv"], cfg.rwkv_args(), x, state)
        return x, aux
    h = nn.rmsnorm(x, p["ln1"], cfg.norm_eps)
    if kind["mixer"] == "mamba":
        x = x + mamba.apply(p["mamba"], cfg.mamba_args(), h)
    else:
        x = x + attention.apply(
            p["attn"], cfg.attn_args(kind["mixer"] == "attn_local"), h)
    h = nn.rmsnorm(x, p["ln2"], cfg.norm_eps)
    if kind["ffn"] == "moe":
        y, aux = moe.apply(p["moe"], cfg.moe_args(), h)
        x = x + y
    else:
        x = x + mlp.apply(p["mlp"], h)
    return x, aux


def _embed_inputs(cfg: ArchConfig, params, tokens: jnp.ndarray,
                  frontend_embeds: Optional[jnp.ndarray]) -> jnp.ndarray:
    x = params["embed"][tokens]
    if frontend_embeds is not None:
        x = jnp.concatenate([frontend_embeds.astype(x.dtype), x], axis=1)
    return logical.constrain(x, "batch", "seq", "embed")


def forward(params, cfg: ArchConfig, tokens: jnp.ndarray,
            frontend_embeds: Optional[jnp.ndarray] = None,
            remat: bool = True) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """tokens [B,S] -> (logits [B,S_total,V], moe aux scalar)."""
    x = _embed_inputs(cfg, params, tokens, frontend_embeds)

    def period_fn(x, pparams):
        aux = jnp.float32(0.0)
        for pos in range(cfg.period):
            x, a = _apply_block(cfg, pos, pparams[pos], x)
            aux = aux + a
        return x, aux

    if remat:
        import os as _os
        if _os.environ.get("REPRO_REMAT_POLICY") == "dots":
            # SSPerf lever: keep matmul outputs, recompute elementwise only
            body = jax.checkpoint(
                period_fn,
                policy=jax.checkpoint_policies
                .dots_with_no_batch_dims_saveable)
        else:
            body = jax.checkpoint(period_fn)
    else:
        body = period_fn
    # scan over periods; xs = tuple of per-position trees, leaves [n_periods,..]
    x, auxs = jax.lax.scan(body, x, tuple(params["blocks"]))
    x = nn.rmsnorm(x, params["ln_f"], cfg.norm_eps)
    logits = nn.dense(x, params["head"]).astype(jnp.float32)
    logits = logical.constrain(logits, "batch", "seq", "vocab")
    return logits, jnp.sum(auxs)


def loss_fn(params, cfg: ArchConfig, batch: Dict[str, jnp.ndarray]
            ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """batch: tokens [B,S], targets [B,S] (+ frontend_embeds [B,F,d])."""
    fe = batch.get("frontend_embeds")
    logits, aux = forward(params, cfg, batch["tokens"], fe)
    f = 0 if fe is None else fe.shape[1]
    logits = logits[:, f:, :]
    xent = nn.softmax_xent(logits, batch["targets"], batch.get("mask"))
    loss = xent + aux
    return loss, {"xent": xent, "aux": aux}


# ------------------------------------------------------------- serving

def init_caches(cfg: ArchConfig, batch: int, max_len: int,
                dtype=jnp.bfloat16) -> List[Any]:
    """Per-pattern-position caches, leaves stacked over periods."""
    caches = []
    for pos in range(cfg.period):
        kind = cfg.layer_kind(pos)
        if kind["mixer"] == "rwkv":
            c = rwkv.init_state(cfg.rwkv_args(), batch)
        elif kind["mixer"] == "mamba":
            c = mamba.init_cache(cfg.mamba_args(), batch, dtype)
        else:
            hkv = cfg.attn_args(False).hkv
            c = {"k": jnp.zeros((batch, hkv, max_len, cfg.d_head), dtype),
                 "v": jnp.zeros((batch, hkv, max_len, cfg.d_head), dtype)}
        stacked = jax.tree.map(
            lambda a: jnp.broadcast_to(a[None],
                                       (cfg.n_periods,) + a.shape), c)
        caches.append(stacked)
    return caches


def decode_step(params, cfg: ArchConfig, token: jnp.ndarray,
                caches: List[Any], cache_len: jnp.ndarray
                ) -> Tuple[jnp.ndarray, List[Any]]:
    """token [B] int32 -> (logits [B,V], updated caches).

    cache_len [B]: current filled length (same for all layers).
    """
    x = params["embed"][token][:, None, :]              # [B,1,d]

    def one_block(x, pos, p, c):
        kind = cfg.layer_kind(pos)
        if kind["mixer"] == "rwkv":
            return rwkv.apply(p["rwkv"], cfg.rwkv_args(), x, c)
        h = nn.rmsnorm(x, p["ln1"], cfg.norm_eps)
        if kind["mixer"] == "mamba":
            y, c = mamba.decode_step(p["mamba"], cfg.mamba_args(), h, c)
            x = x + y
        else:
            y, c = attention.decode_step(
                p["attn"], cfg.attn_args(kind["mixer"] == "attn_local"),
                h, c, cache_len)
            x = x + y
        h = nn.rmsnorm(x, p["ln2"], cfg.norm_eps)
        x = _ffn(cfg, kind, p, x, h)
        return x, c

    def period_fn(x, inp):
        pparams, pcaches = inp
        newc = []
        for pos in range(cfg.period):
            x, c = one_block(x, pos, pparams[pos], pcaches[pos])
            newc.append(c)
        return x, tuple(newc)

    x, new_caches = jax.lax.scan(
        period_fn, x, (tuple(params["blocks"]), tuple(caches)))
    x = nn.rmsnorm(x, params["ln_f"], cfg.norm_eps)
    logits = nn.dense(x[:, 0], params["head"]).astype(jnp.float32)
    return logits, list(new_caches)


def prefill(params, cfg: ArchConfig, tokens: jnp.ndarray,
            max_len: int,
            frontend_embeds: Optional[jnp.ndarray] = None
            ) -> Tuple[jnp.ndarray, List[Any], jnp.ndarray]:
    """Prefill the caches with a full prompt; returns (last-token logits,
    caches padded to max_len, cache_len [B])."""
    x = _embed_inputs(cfg, params, tokens, frontend_embeds)
    b, s, _ = x.shape

    def one_block(x, pos, p):
        kind = cfg.layer_kind(pos)
        if kind["mixer"] == "rwkv":
            st = rwkv.init_state(cfg.rwkv_args(), b)
            return rwkv.apply(p["rwkv"], cfg.rwkv_args(), x, st)
        h = nn.rmsnorm(x, p["ln1"], cfg.norm_eps)
        if kind["mixer"] == "mamba":
            y = mamba.apply(p["mamba"], cfg.mamba_args(), h)
            x = x + y
            c = _mamba_tail_state(p["mamba"], cfg.mamba_args(), h)
        else:
            aargs = cfg.attn_args(kind["mixer"] == "attn_local")
            y, kv = attention.apply_and_cache(p["attn"], aargs, h)
            x = x + y
            c = {k: _pad_cache(v, max_len) for k, v in kv.items()}
        hh = nn.rmsnorm(x, p["ln2"], cfg.norm_eps)
        x = _ffn(cfg, kind, p, x, hh)
        return x, c

    def period_fn(x, pparams):
        newc = []
        for pos in range(cfg.period):
            x, c = one_block(x, pos, pparams[pos])
            newc.append(c)
        return x, tuple(newc)

    x, new_caches = jax.lax.scan(period_fn, x, tuple(params["blocks"]))
    x = nn.rmsnorm(x, params["ln_f"], cfg.norm_eps)
    logits = nn.dense(x[:, -1], params["head"]).astype(jnp.float32)
    cache_len = jnp.full((b,), s, jnp.int32)
    return logits, list(new_caches), cache_len


def _ffn(cfg, kind, p, x, h):
    if kind["ffn"] == "moe":
        y, _ = moe.apply(p["moe"], cfg.moe_args(), h)
        return x + y
    if kind["ffn"] is None:
        return x
    return x + mlp.apply(p["mlp"], h)


def _pad_cache(kv: jnp.ndarray, max_len: int) -> jnp.ndarray:
    b, h, s, d = kv.shape
    if s >= max_len:
        return kv[:, :, :max_len]
    return jnp.pad(kv, ((0, 0), (0, 0), (0, max_len - s), (0, 0)))


def _mamba_tail_state(p, a: mamba.MambaArgs, h: jnp.ndarray):
    """Decode cache after a prefill: conv tail + SSM state of the last chunk.

    Approximation-free for the conv window; the SSM state is recomputed by
    scanning the full sequence once more at chunk granularity (cheap: the
    scan is the same cost as the forward pass's state propagation).
    """
    xz = nn.dense(h, p["in_proj"])
    u, _ = jnp.split(xz, 2, axis=-1)
    conv_tail = u[:, -(a.d_conv - 1):, :]
    uc = jax.nn.silu(mamba._causal_conv(u, p["conv_w"], p["conv_b"]))
    bsz, s, _ = uc.shape
    a_mat = -jnp.exp(p["a_log"].astype(jnp.float32))
    ch = min(a.chunk, s)
    ucc = jnp.moveaxis(uc.reshape(bsz, s // ch, ch, -1), 1, 0)

    def body(hst, u_ch):
        dt, bc, _ = mamba._ssm_params(p, a, u_ch)
        dtf = dt.astype(jnp.float32)
        ea = jnp.exp(dtf[..., None] * a_mat[None, None])
        bu = (dtf * u_ch.astype(jnp.float32))[..., None] \
            * bc.astype(jnp.float32)[..., None, :]

        def comb(l, r):
            return (l[0] * r[0], r[0] * l[1] + r[1])

        ea_s, bu_s = jax.lax.associative_scan(comb, (ea, bu), axis=1)
        return ea_s[:, -1] * hst + bu_s[:, -1], None

    h0 = jnp.zeros((bsz, a.d_inner, a.d_state), jnp.float32)
    hend, _ = jax.lax.scan(body, h0, ucc)
    return {"conv": conv_tail, "h": hend}
