"""Minimal functional module system: params as pytrees, specs as trees.

No flax/haiku on this box, and a framework should own its parameter story
anyway: a model is described once as a tree of `ParamSpec`s (shape + logical
sharding axes + initializer); `init_tree` realises it into arrays (per-leaf
deterministic keys from the tree path) and `axes_tree` extracts the logical
axis signature consumed by `repro.sharding.logical`.

Apply-side helpers (rmsnorm, dense, rope) are plain functions over the
realised params.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Axes = Tuple[Optional[str], ...]


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    axes: Axes                       # logical sharding axes, len == ndim
    init: str = "normal"             # normal | zeros | ones | const
    scale: float = 1.0               # stddev for normal / value for const

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def dense_spec(d_in: int, d_out: int, axes: Axes,
               scale: Optional[float] = None) -> ParamSpec:
    s = scale if scale is not None else 1.0 / np.sqrt(d_in)
    return ParamSpec((d_in, d_out), axes, "normal", s)


def _is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def init_tree(specs: Any, key: jax.Array, dtype=jnp.float32) -> Any:
    """Realise a ParamSpec tree; every leaf gets a path-derived key."""
    leaves, treedef = jax.tree.flatten(specs, is_leaf=_is_spec)
    paths = jax.tree_util.tree_flatten_with_path(specs, is_leaf=_is_spec)[0]
    out = []
    for (path, spec) in paths:
        pkey = jax.random.fold_in(key, hash(jax.tree_util.keystr(path))
                                  % (2 ** 31))
        if spec.init == "normal":
            a = jax.random.normal(pkey, spec.shape, jnp.float32) * spec.scale
        elif spec.init == "zeros":
            a = jnp.zeros(spec.shape, jnp.float32)
        elif spec.init == "ones":
            a = jnp.ones(spec.shape, jnp.float32)
        elif spec.init == "const":
            a = jnp.full(spec.shape, spec.scale, jnp.float32)
        else:
            raise ValueError(spec.init)
        out.append(a.astype(dtype))
    return jax.tree.unflatten(treedef, out)


def axes_tree(specs: Any) -> Any:
    """ParamSpec tree -> tree of logical-axis tuples (same structure)."""
    return jax.tree.map(lambda s: s.axes, specs, is_leaf=_is_spec)


def shape_tree(specs: Any) -> Any:
    return jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32),
                        specs, is_leaf=_is_spec)


def param_count(specs: Any) -> int:
    return sum(int(np.prod(s.shape))
               for s in jax.tree.leaves(specs, is_leaf=_is_spec))


# ------------------------------------------------------------- apply-side

def rmsnorm(x: jnp.ndarray, gamma: jnp.ndarray,
            eps: float = 1e-6) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * gamma.astype(jnp.float32)
            ).astype(dt)


def dense(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    return jnp.einsum("...d,df->...f", x, w.astype(x.dtype))


def rope(x: jnp.ndarray, positions: jnp.ndarray,
         theta: float = 10000.0) -> jnp.ndarray:
    """Rotary embedding. x: [..., S, H, D] (D even); positions: [..., S]."""
    d = x.shape[-1]
    freqs = 1.0 / (theta ** (jnp.arange(0, d, 2, dtype=jnp.float32) / d))
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # [..., S, D/2]
    cos = jnp.cos(ang)[..., None, :]                           # [..., S, 1, D/2]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


def swiglu(x: jnp.ndarray, wg: jnp.ndarray, wu: jnp.ndarray,
           wd: jnp.ndarray) -> jnp.ndarray:
    h = jax.nn.silu(dense(x, wg)) * dense(x, wu)
    return dense(h, wd)


def softmax_xent(logits: jnp.ndarray, targets: jnp.ndarray,
                 mask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Mean masked token cross-entropy; fp32 logsumexp (vocab may be
    model-sharded: GSPMD turns the reductions into psums)."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
