"""RWKV-6 "Finch" block: attention-free, data-dependent decay recurrence.

Faithful to the headline mechanism of arXiv:2404.05892: per-channel decays
w_t are *functions of the input* (low-rank MLP), the WKV state is a per-head
[dh, dh] outer-product accumulator

    wkv_t = r_t . (S_{t-1} + (u * k_t) v_t^T)
    S_t   = diag(w_t) S_{t-1} + k_t v_t^T

plus token-shift mixing and the squared-ReLU channel-mix FFN.  The receptance
/key/value/gate mixing coefficients use static learned mus (the LoRA ddlerp
refinement of the paper is folded into the decay path, which is the part
that carries the "data-dependent decay" contribution).

Training/prefill scans the sequence (state [B,H,dh,dh] is the only carry);
decode is O(1) per token with no KV cache -- hence rwkv6 runs long_500k.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

# SSPerf knob: carry the WKV state in bf16 (halves the dominant HBM tensor
# of the training scan; decay products stay fp32 for stability)
STATE_BF16 = os.environ.get("REPRO_RWKV_STATE_BF16", "0") == "1"

from repro.models import modules as nn
from repro.sharding import logical


@dataclasses.dataclass(frozen=True)
class RWKVArgs:
    d_model: int
    d_ff: int
    head_dim: int = 64
    decay_rank: int = 64

    @property
    def n_heads(self) -> int:
        return self.d_model // self.head_dim


def specs(a: RWKVArgs) -> Dict[str, nn.ParamSpec]:
    d = a.d_model
    return {
        "ln1": nn.ParamSpec((d,), ("embed",), "ones"),
        "ln2": nn.ParamSpec((d,), ("embed",), "ones"),
        "tm": {  # time-mix
            "mu_r": nn.ParamSpec((d,), ("embed",), "const", 0.5),
            "mu_k": nn.ParamSpec((d,), ("embed",), "const", 0.5),
            "mu_v": nn.ParamSpec((d,), ("embed",), "const", 0.5),
            "mu_g": nn.ParamSpec((d,), ("embed",), "const", 0.5),
            "mu_w": nn.ParamSpec((d,), ("embed",), "const", 0.5),
            "wr": nn.dense_spec(d, d, ("embed", "q_flat")),
            "wk": nn.dense_spec(d, d, ("embed", "q_flat")),
            "wv": nn.dense_spec(d, d, ("embed", "q_flat")),
            "wg": nn.dense_spec(d, d, ("embed", "q_flat")),
            "wo": nn.dense_spec(d, d, ("q_flat", "embed")),
            # data-dependent decay: w = exp(-exp(w0 + tanh(x A) B))
            "w0": nn.ParamSpec((d,), ("embed",), "const", -0.6),
            "wa": nn.dense_spec(d, a.decay_rank, ("embed", None), 0.01),
            "wb": nn.dense_spec(a.decay_rank, d, (None, "embed"), 0.01),
            "u": nn.ParamSpec((d,), ("embed",), "const", 0.3),  # bonus
        },
        "cm": {  # channel-mix
            "mu_r": nn.ParamSpec((d,), ("embed",), "const", 0.5),
            "mu_k": nn.ParamSpec((d,), ("embed",), "const", 0.5),
            "wr": nn.dense_spec(d, d, ("embed", None)),
            "wk": nn.dense_spec(d, a.d_ff, ("embed", "mlp")),
            "wv": nn.dense_spec(a.d_ff, d, ("mlp", "embed")),
        },
    }


def _shift(x: jnp.ndarray) -> jnp.ndarray:
    return jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]


def _chunk_size(s: int, target: int = 64) -> int:
    """Largest divisor of s not exceeding target."""
    ch = min(target, s)
    while s % ch:
        ch -= 1
    return ch


def _mix(x, xprev, mu):
    return x + (xprev - x) * mu.astype(x.dtype)[None, None, :]


def _decay(tm, xw: jnp.ndarray) -> jnp.ndarray:
    dd = nn.dense(jnp.tanh(nn.dense(xw, tm["wa"])), tm["wb"])
    return jnp.exp(-jnp.exp(
        tm["w0"].astype(jnp.float32)[None, None] + dd.astype(jnp.float32)))


def _heads(x: jnp.ndarray, h: int, dh: int) -> jnp.ndarray:
    return x.reshape(*x.shape[:-1], h, dh)


def time_mix(tm, a: RWKVArgs, x: jnp.ndarray,
             state: jnp.ndarray, x_last: jnp.ndarray
             ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """x: [B,S,d]; state: [B,H,dh,dh] fp32; x_last: [B,d] (shift carry).
    Returns (out, new_state, new_x_last)."""
    b, s, d = x.shape
    h, dh = a.n_heads, a.head_dim
    xprev = _shift(x).at[:, 0].set(x_last.astype(x.dtype))
    r = _heads(nn.dense(_mix(x, xprev, tm["mu_r"]), tm["wr"]), h, dh)
    k = _heads(nn.dense(_mix(x, xprev, tm["mu_k"]), tm["wk"]), h, dh)
    v = _heads(nn.dense(_mix(x, xprev, tm["mu_v"]), tm["wv"]), h, dh)
    g = nn.dense(_mix(x, xprev, tm["mu_g"]), tm["wg"])
    w = _heads(_decay(tm, _mix(x, xprev, tm["mu_w"])), h, dh)  # [B,S,H,dh]
    u = _heads(tm["u"].astype(jnp.float32), h, dh)             # [H,dh]

    sdt = jnp.bfloat16 if STATE_BF16 else jnp.float32
    rf = r.astype(sdt)
    kf = k.astype(sdt)
    vf = v.astype(sdt)
    state = state.astype(sdt)

    def step(S, inp):
        rt, kt, vt, wt = inp                                # [B,H,dh]
        kv = kt[..., :, None] * vt[..., None, :]            # [B,H,dh,dh]
        out = jnp.einsum("bhk,bhkv->bhv", rt, S + (u[None][..., None]
                                                   .astype(sdt) * kv))
        S = wt.astype(sdt)[..., None] * S + kv
        return S, out

    # chunked double scan: the checkpointed outer scan keeps only chunk-
    # boundary WKV states for the backward pass (a 4k-step single scan
    # would otherwise stash a [B,H,dh,dh] state per *token*)
    ch = _chunk_size(s)

    def seq_first(t):                                       # [n,ch,B,H,dh]
        return jnp.moveaxis(t, 1, 0).reshape(
            (s // ch, ch) + t.shape[0:1] + t.shape[2:])

    xs = (seq_first(rf), seq_first(kf), seq_first(vf), seq_first(w))

    @jax.checkpoint
    def chunk(S, inp):
        return jax.lax.scan(step, S, inp)

    state, outs = jax.lax.scan(chunk, state, xs)
    out = jnp.moveaxis(outs.reshape((s,) + outs.shape[2:]), 0, 1)
    out = out.reshape(b, s, d).astype(x.dtype)
    out = out * jax.nn.silu(g)
    out = logical.constrain(out, "batch", "seq", "q_flat")
    return nn.dense(out, tm["wo"]), state, x[:, -1]


def channel_mix(cm, x: jnp.ndarray, x_last: jnp.ndarray
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    xprev = _shift(x).at[:, 0].set(x_last.astype(x.dtype))
    r = jax.nn.sigmoid(nn.dense(_mix(x, xprev, cm["mu_r"]), cm["wr"]))
    k = nn.dense(_mix(x, xprev, cm["mu_k"]), cm["wk"])
    k = jnp.square(jax.nn.relu(k))
    return r * nn.dense(k, cm["wv"]), x[:, -1]


def init_state(a: RWKVArgs, batch: int) -> Dict[str, jnp.ndarray]:
    return {
        "S": jnp.zeros((batch, a.n_heads, a.head_dim, a.head_dim),
                       jnp.float32),
        "x_tm": jnp.zeros((batch, a.d_model), jnp.float32),
        "x_cm": jnp.zeros((batch, a.d_model), jnp.float32),
    }


def apply(p, a: RWKVArgs, x: jnp.ndarray, state: Dict
          ) -> Tuple[jnp.ndarray, Dict]:
    """One full RWKV block (time-mix + channel-mix), pre-norm residuals."""
    y, s_new, xtm = time_mix(p["tm"], a, nn.rmsnorm(x, p["ln1"]),
                             state["S"], state["x_tm"])
    x = x + y
    y, xcm = channel_mix(p["cm"], nn.rmsnorm(x, p["ln2"]), state["x_cm"])
    x = x + y
    return x, {"S": s_new, "x_tm": xtm, "x_cm": xcm}
