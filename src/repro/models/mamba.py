"""Mamba (S6) selective-state-space block for the jamba hybrid.

TPU-adapted selective scan: instead of the CUDA fused kernel, the recurrence
    h_t = a_t * h_{t-1} + b_t,   a_t = exp(dt_t * A),  b_t = dt_t * B_t * u_t
runs as an outer `lax.scan` over sequence *chunks* with an inner associative
scan inside each chunk -- the [B, S, d_inner, d_state] discretised tensor is
never materialised beyond one chunk (HBM-bounded, remat-friendly), which is
the part of the original kernel's job that matters on TPU.

Decode is the O(1) single-step update on carried state
(conv window + SSM state) -- why jamba runs the long_500k shape.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import modules as nn
from repro.sharding import logical


@dataclasses.dataclass(frozen=True)
class MambaArgs:
    d_model: int
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0              # 0 -> ceil(d_model / 16)
    chunk: int = 256

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def rank(self) -> int:
        return self.dt_rank or -(-self.d_model // 16)


def specs(a: MambaArgs) -> Dict[str, nn.ParamSpec]:
    di = a.d_inner
    return {
        "in_proj": nn.dense_spec(a.d_model, 2 * di, ("embed", "ssm_inner")),
        "conv_w": nn.ParamSpec((a.d_conv, di), (None, "ssm_inner"),
                               "normal", 0.5),
        "conv_b": nn.ParamSpec((di,), ("ssm_inner",), "zeros"),
        "x_proj": nn.dense_spec(di, a.rank + 2 * a.d_state,
                                ("ssm_inner", None)),
        "dt_proj": nn.dense_spec(a.rank, di, (None, "ssm_inner")),
        "dt_bias": nn.ParamSpec((di,), ("ssm_inner",), "const", 0.1),
        "a_log": nn.ParamSpec((di, a.d_state), ("ssm_inner", "ssm_state"),
                              "const", 0.0),
        "d_skip": nn.ParamSpec((di,), ("ssm_inner",), "ones"),
        "out_proj": nn.dense_spec(di, a.d_model, ("ssm_inner", "embed")),
    }


def _causal_conv(u: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray
                 ) -> jnp.ndarray:
    """Depthwise causal conv along seq.  u: [B,S,di]; w: [K,di]."""
    k = w.shape[0]
    up = jnp.pad(u, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(up[:, i:i + u.shape[1], :] * w[i][None, None, :]
              for i in range(k))
    return out + b[None, None, :]


def _ssm_params(p, a: MambaArgs, u: jnp.ndarray):
    """u: [..., di] -> (dt [...,di], Bc [...,ds], Cc [...,ds])."""
    z = nn.dense(u, p["x_proj"])
    dt, bc, cc = jnp.split(z, [a.rank, a.rank + a.d_state], axis=-1)
    dt = jax.nn.softplus(nn.dense(dt, p["dt_proj"])
                         + p["dt_bias"].astype(u.dtype))
    return dt, bc, cc


def apply(p, a: MambaArgs, x: jnp.ndarray) -> jnp.ndarray:
    """Full-sequence training/prefill pass.  x: [B,S,d]."""
    bsz, s, _ = x.shape
    xz = nn.dense(x, p["in_proj"])
    u, gate = jnp.split(xz, 2, axis=-1)                    # [B,S,di]
    u = jax.nn.silu(_causal_conv(u, p["conv_w"], p["conv_b"]))
    u = logical.constrain(u, "batch", "seq", "ssm_inner")

    ch = min(a.chunk, s)
    assert s % ch == 0, (s, ch)
    a_mat = -jnp.exp(p["a_log"].astype(jnp.float32))       # [di, ds]

    uc = jnp.moveaxis(u.reshape(bsz, s // ch, ch, -1), 1, 0)

    @jax.checkpoint
    def chunk_body(h, u_ch):
        # u_ch: [B, ch, di]; h: [B, di, ds] fp32
        dt, bc, cc = _ssm_params(p, a, u_ch)
        dtf = dt.astype(jnp.float32)
        ea = jnp.exp(dtf[..., None] * a_mat[None, None])   # [B,ch,di,ds]
        bu = (dtf * u_ch.astype(jnp.float32))[..., None] \
            * bc.astype(jnp.float32)[..., None, :]          # [B,ch,di,ds]

        def comb(l, r):
            return (l[0] * r[0], r[0] * l[1] + r[1])

        ea_s, bu_s = jax.lax.associative_scan(comb, (ea, bu), axis=1)
        hs = ea_s * h[:, None] + bu_s                      # [B,ch,di,ds]
        y = jnp.einsum("bcds,bcs->bcd", hs, cc.astype(jnp.float32))
        y = y + p["d_skip"].astype(jnp.float32) * u_ch.astype(jnp.float32)
        return hs[:, -1], y.astype(x.dtype)

    h0 = jnp.zeros((bsz, a.d_inner, a.d_state), jnp.float32)
    _, ys = jax.lax.scan(chunk_body, h0, uc)
    y = jnp.moveaxis(ys, 0, 1).reshape(bsz, s, a.d_inner)
    y = y * jax.nn.silu(gate)
    return nn.dense(y, p["out_proj"])


def init_cache(a: MambaArgs, batch: int, dtype=jnp.float32
               ) -> Dict[str, jnp.ndarray]:
    return {
        "conv": jnp.zeros((batch, a.d_conv - 1, a.d_inner), dtype),
        "h": jnp.zeros((batch, a.d_inner, a.d_state), jnp.float32),
    }


def decode_step(p, a: MambaArgs, x1: jnp.ndarray, cache: Dict
                ) -> Tuple[jnp.ndarray, Dict]:
    """O(1) decode.  x1: [B,1,d]."""
    xz = nn.dense(x1[:, 0], p["in_proj"])
    u, gate = jnp.split(xz, 2, axis=-1)                    # [B,di]
    win = jnp.concatenate([cache["conv"], u[:, None]], axis=1)  # [B,K,di]
    conv = jnp.einsum("bkd,kd->bd", win, p["conv_w"].astype(u.dtype)) \
        + p["conv_b"].astype(u.dtype)
    u = jax.nn.silu(conv)
    dt, bc, cc = _ssm_params(p, a, u)
    a_mat = -jnp.exp(p["a_log"].astype(jnp.float32))
    ea = jnp.exp(dt.astype(jnp.float32)[..., None] * a_mat[None])
    bu = (dt * u)[..., None].astype(jnp.float32) \
        * bc.astype(jnp.float32)[:, None, :]
    h = ea * cache["h"] + bu
    y = jnp.einsum("bds,bs->bd", h, cc.astype(jnp.float32)) \
        + p["d_skip"].astype(jnp.float32) * u.astype(jnp.float32)
    y = (y.astype(x1.dtype) * jax.nn.silu(gate))
    out = nn.dense(y, p["out_proj"])[:, None, :]
    return out, {"conv": win[:, 1:], "h": h}
