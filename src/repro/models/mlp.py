"""Dense SwiGLU MLP (llama-family FFN used by every dense assigned arch)."""
from __future__ import annotations

from typing import Dict

import jax.numpy as jnp

from repro.models import modules as nn
from repro.sharding import logical


def specs(d_model: int, d_ff: int) -> Dict[str, nn.ParamSpec]:
    return {
        "wg": nn.dense_spec(d_model, d_ff, ("embed", "mlp")),
        "wu": nn.dense_spec(d_model, d_ff, ("embed", "mlp")),
        "wd": nn.dense_spec(d_ff, d_model, ("mlp", "embed")),
    }


def apply(p, x: jnp.ndarray) -> jnp.ndarray:
    h = nn.swiglu(x, p["wg"], p["wu"], p["wd"])
    return logical.constrain(h, "batch", "seq", "embed")
