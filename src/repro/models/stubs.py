"""Modality frontend stubs for [vlm] / [audio] architectures.

Per the assignment, llava-next and musicgen are specified as transformer
BACKBONES only: the vision tower / EnCodec tokenizer are stubs whose output
-- precomputed patch/frame embeddings in d_model -- arrives as a model input
(`input_specs` supplies the ShapeDtypeStruct; tests synthesise them).  The
backbone prepends them to the token embeddings and masks them out of the LM
loss, which is exactly how the real models consume their frontends.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

# anyres default tile of llava-next (24x24 patches); musicgen: 50 Hz frames
FRONTEND_TOKENS = {"vision": 576, "audio": 250}


def frontend_tokens(kind: Optional[str], override: int = 0) -> int:
    if kind is None:
        return 0
    return override or FRONTEND_TOKENS[kind]


def synth_frontend(key: jax.Array, kind: str, batch: int, n_tokens: int,
                   d_model: int, dtype=jnp.bfloat16) -> jnp.ndarray:
    """Deterministic stand-in embeddings for tests/examples."""
    scale = 0.02 if kind == "vision" else 0.05
    return (jax.random.normal(key, (batch, n_tokens, d_model), jnp.float32)
            * scale).astype(dtype)


def frontend_spec(kind: Optional[str], batch: int, n_tokens: int,
                  d_model: int) -> Optional[jax.ShapeDtypeStruct]:
    if kind is None:
        return None
    return jax.ShapeDtypeStruct((batch, n_tokens, d_model), jnp.bfloat16)
