"""Attention layers: GQA + RoPE, sliding-window locals, KV-cache decode.

Sharding strategy (resolved per-arch by `sharding.logical` rules):
  * train/prefill: Q/K/V projections sharded on the flattened head dim
    ("q_flat"/"kv_flat" -> model axis; divides for every assigned arch,
    including llava's 56 heads where per-head sharding is impossible);
    attention compute shards over heads when divisible, else GSPMD falls
    back per the constraint propagation.
  * decode: the KV cache is *sequence*-sharded over the model axis
    ("kv_seq" rule) and merged with a log-sum-exp psum -- the flash-decoding
    split-KV scheme.  This sidesteps GQA head-divisibility entirely and
    scales cache memory 1/model_parallelism; one token's K/V is written by
    exactly the owning shard.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.runtime import jaxcompat
from repro.kernels import ops
from repro.models import modules as nn
from repro.sharding import logical


@dataclasses.dataclass(frozen=True)
class AttnArgs:
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    rope_theta: float = 10000.0
    window: Optional[int] = None     # sliding-window size for local layers
    # head padding (SSPerf): physical head counts rounded up so they divide
    # the model axis (llava: 56 -> 64 q, 8 -> 16 kv).  Padded heads are
    # hard-masked to zero after attention, so the model function is exactly
    # the unpadded one (zero output -> zero gradient into padded weights).
    pad_q_heads: int = 0
    pad_kv_heads: int = 0

    @property
    def hq(self) -> int:
        return self.pad_q_heads or self.n_heads

    @property
    def hkv(self) -> int:
        return self.pad_kv_heads or self.n_kv_heads


def specs(a: AttnArgs) -> Dict[str, nn.ParamSpec]:
    return {
        "wq": nn.dense_spec(a.d_model, a.hq * a.d_head,
                            ("embed", "q_flat")),
        "wk": nn.dense_spec(a.d_model, a.hkv * a.d_head,
                            ("embed", "kv_flat")),
        "wv": nn.dense_spec(a.d_model, a.hkv * a.d_head,
                            ("embed", "kv_flat")),
        "wo": nn.dense_spec(a.hq * a.d_head, a.d_model,
                            ("q_flat", "embed")),
    }


def _project_qkv(p, a: AttnArgs, x: jnp.ndarray, positions: jnp.ndarray
                 ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """x: [B,S,d] -> q [B,S,Hq,dh], k/v [B,S,Hkv,dh] with RoPE applied."""
    b, s, _ = x.shape
    q = nn.dense(x, p["wq"])
    k = nn.dense(x, p["wk"])
    v = nn.dense(x, p["wv"])
    q = logical.constrain(q, "batch", "seq", "q_flat")
    k = logical.constrain(k, "batch", "seq", "kv_flat")
    v = logical.constrain(v, "batch", "seq", "kv_flat")
    q = q.reshape(b, s, a.hq, a.d_head)
    k = k.reshape(b, s, a.hkv, a.d_head)
    v = v.reshape(b, s, a.hkv, a.d_head)
    q = nn.rope(q, positions, a.rope_theta)
    k = nn.rope(k, positions, a.rope_theta)
    return q, k, v


def _mask_padded(a: AttnArgs, out_heads: jnp.ndarray) -> jnp.ndarray:
    """Zero the padded heads' outputs ([..., H, dh] layout, H on axis -2)."""
    if a.hq == a.n_heads:
        return out_heads
    mask = (jnp.arange(a.hq) < a.n_heads).astype(out_heads.dtype)
    return out_heads * mask[..., :, None]


# sliding-window layers switch to the sub-quadratic banded path when the
# window is much shorter than the sequence (toggle = SSPerf ablation lever)
import os as _os  # noqa: E402

USE_BANDED = _os.environ.get("REPRO_BANDED", "1") == "1"


def _attend_full(a: AttnArgs, qt, kt, vt):
    s, t = qt.shape[2], kt.shape[2]
    if (USE_BANDED and a.window is not None and s == t
            and s >= 4 * a.window):
        from repro.kernels import xla_flash
        return xla_flash.banded_attention_xla(qt, kt, vt, a.window)
    return ops.flash_attention(qt, kt, vt, True, a.window, None)


def _heads_shardable(a: AttnArgs) -> bool:
    ctx = logical.current()
    if ctx is None:
        return True
    spec = logical.spec_for(("heads",), (a.n_heads,), *ctx)
    return spec[0] is not None


def apply(p, a: AttnArgs, x: jnp.ndarray,
          positions: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Full-sequence causal attention (train / prefill)."""
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.arange(s)[None, :]
    q, k, v = _project_qkv(p, a, x, positions)
    qt = jnp.swapaxes(q, 1, 2)          # [B,H,S,dh]
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    # NOTE(SSPerf, refuted): forcing query-sequence sharding with whole K/V
    # here quadrupled collective time on llava (full K/V all-gathered per
    # layer); the winning layout is data-parallel attention with replicated
    # (FSDP-gathered) attention weights -- a *rules* choice, not a constraint
    # (see EXPERIMENTS.md SSPerf llava iterations).
    qt = logical.constrain(qt, "batch", "heads", "seq", "head")
    out = _attend_full(a, qt, kt, vt)
    out = _mask_padded(a, jnp.swapaxes(out, 1, 2))
    out = out.reshape(b, s, a.hq * a.d_head)
    out = logical.constrain(out, "batch", "seq", "q_flat")
    return nn.dense(out, p["wo"])


def apply_and_cache(p, a: AttnArgs, x: jnp.ndarray
                    ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Prefill: attention output + KV cache [B,Hkv,S,dh] (seq-shardable)."""
    b, s, _ = x.shape
    positions = jnp.arange(s)[None, :]
    q, k, v = _project_qkv(p, a, x, positions)
    qt, kt, vt = (jnp.swapaxes(t, 1, 2) for t in (q, k, v))
    out = _attend_full(a, qt, kt, vt)
    out = _mask_padded(a, jnp.swapaxes(out, 1, 2))
    out = out.reshape(b, s, a.hq * a.d_head)
    y = nn.dense(out, p["wo"])
    cache = {
        "k": logical.constrain(kt, "batch", "kv_heads", "kv_seq", "head"),
        "v": logical.constrain(vt, "batch", "kv_heads", "kv_seq", "head"),
    }
    return y, cache


# ------------------------------------------------------------------ decode

def _local_decode_attend(q, kc, vc, cache_len, base, window, t_total):
    """Partial (unnormalised) attention of one KV shard.

    q: [B,H,dh]; kc/vc: [B,Hkv,Tl,dh] local shard covering absolute
    positions [base, base+Tl); returns (m, l, o) for LSE merging.
    """
    b, h, d = q.shape
    hkv, tl = kc.shape[1], kc.shape[2]
    g = h // hkv
    kx = jnp.repeat(kc, g, axis=1).astype(jnp.float32)
    vx = jnp.repeat(vc, g, axis=1).astype(jnp.float32)
    scale = 1.0 / (d ** 0.5)
    logits = jnp.einsum("bhd,bhtd->bht", q.astype(jnp.float32) * scale, kx)
    pos = base + jnp.arange(tl)[None, :]                      # [1, Tl]
    valid = pos < cache_len[:, None]                          # [B, Tl]
    if window is not None:
        valid = valid & (pos > cache_len[:, None] - 1 - window)
    logits = jnp.where(valid[:, None, :], logits, -jnp.inf)
    m = jnp.max(logits, axis=-1)                              # [B,H]
    msafe = jnp.where(jnp.isfinite(m), m, 0.0)
    pr = jnp.where(jnp.isfinite(logits), jnp.exp(logits - msafe[..., None]),
                   0.0)
    l = jnp.sum(pr, axis=-1)
    o = jnp.einsum("bht,bhtd->bhd", pr, vx)
    m = jnp.where(jnp.isfinite(m), m, -1e30)
    return m, l, o


def decode_step(p, a: AttnArgs, x1: jnp.ndarray, cache: Dict[str, jnp.ndarray],
                cache_len: jnp.ndarray
                ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """One-token decode.  x1: [B,1,d]; cache k/v: [B,Hkv,T,dh];
    cache_len: [B] (current filled length; new token lands at cache_len).

    With an active mesh whose "kv_seq" rule shards the cache sequence, runs
    the shard_map split-KV scheme; otherwise a single-device path.
    """
    b = x1.shape[0]
    positions = cache_len[:, None]
    q, k1, v1 = _project_qkv(p, a, x1, positions)
    q1 = q[:, 0]                                   # [B,H,dh]
    k1 = jnp.swapaxes(k1, 1, 2)                    # [B,Hkv,1,dh]
    v1 = jnp.swapaxes(v1, 1, 2)

    ctx = logical.current()
    t_total = cache["k"].shape[2]
    if ctx is not None:
        mesh, rules = ctx
        kv_axes = rules.get("kv_seq")
        shards = logical._axes_size(mesh, kv_axes) if kv_axes else 1
    else:
        mesh, rules, kv_axes, shards = None, None, None, 1

    if shards > 1 and t_total % shards == 0:
        axes = (kv_axes,) if isinstance(kv_axes, str) else tuple(kv_axes)
        axes = tuple(ax for ax in axes if ax in mesh.shape)
        # batch stays sharded over its own axes (disjoint from kv_seq)
        b_ax = rules.get("batch")
        b_ax = ((b_ax,) if isinstance(b_ax, str) else tuple(b_ax or ()))
        b_ax = tuple(ax for ax in b_ax
                     if ax in mesh.shape and ax not in axes
                     and b % mesh.shape[ax] == 0)
        bspec = b_ax if len(b_ax) > 1 else (b_ax[0] if b_ax else None)
        kvspec = axes if len(axes) > 1 else axes[0]
        cache_spec = P(bspec, None, kvspec, None)
        repl = P(bspec, None, None)

        def shard_fn(q1s, k1s, v1s, kc, vc, clen):
            tl = kc.shape[2]
            idx = jnp.int32(0)
            for ax in axes:                     # row-major linear shard index
                idx = idx * mesh.shape[ax] + jax.lax.axis_index(ax)
            base = idx * tl
            # write the new token into the owning shard only
            local_pos = clen - base                           # [B]
            own = (local_pos >= 0) & (local_pos < tl)
            posmask = ((jnp.arange(tl)[None, :] == local_pos[:, None])
                       & own[:, None])                        # [B, Tl]
            kc = jnp.where(posmask[:, None, :, None],
                           jnp.broadcast_to(k1s, kc.shape), kc)
            vc = jnp.where(posmask[:, None, :, None],
                           jnp.broadcast_to(v1s, vc.shape), vc)
            new_len = clen + 1
            m, l, o = _local_decode_attend(
                q1s, kc, vc, new_len, base, a.window, t_total)
            mg = jax.lax.pmax(m, axes)
            corr = jnp.exp(m - mg)
            lg = jax.lax.psum(l * corr, axes)
            og = jax.lax.psum(o * corr[..., None], axes)
            out = og / jnp.maximum(lg, 1e-30)[..., None]
            return out.astype(x1.dtype), kc, vc

        out, kc, vc = jaxcompat.shard_map(
            shard_fn, mesh=mesh,
            in_specs=(repl, P(bspec, None, None, None),
                      P(bspec, None, None, None), cache_spec, cache_spec,
                      P(bspec)),
            out_specs=(repl, cache_spec, cache_spec),
        )(q1, k1, v1, cache["k"], cache["v"], cache_len)
        new_cache = {"k": kc, "v": vc}
    else:
        posmask = jnp.arange(t_total)[None, :] == cache_len[:, None]
        kc = jnp.where(posmask[:, None, :, None],
                       jnp.broadcast_to(k1, cache["k"].shape), cache["k"])
        vc = jnp.where(posmask[:, None, :, None],
                       jnp.broadcast_to(v1, cache["v"].shape), cache["v"])
        m, l, o = _local_decode_attend(
            q1, kc, vc, cache_len + 1, 0, a.window, t_total)
        out = (o / jnp.maximum(l, 1e-30)[..., None]).astype(x1.dtype)
        new_cache = {"k": kc, "v": vc}

    out = _mask_padded(a, out)                     # [B,Hq,dh]
    y = nn.dense(out.reshape(b, 1, a.hq * a.d_head), p["wo"])
    return y, new_cache
