"""Mixture-of-Experts layer: shared + fine-grained routed experts.

Covers deepseek-moe (2 shared + 64 routed top-6), qwen2-moe (4 shared + 60
routed top-4, padded to 64 so expert-parallelism divides the model axis;
padded experts are router-masked), and jamba's 16-expert top-2 layers.

Two execution paths:
  * `reference` (no mesh): every expert computed densely, gathered by gate --
    exact, O(E) FLOPs, used by the CPU smoke tests with tiny expert counts.
  * `expert-parallel` (active mesh): shard_map over the model axis.  Tokens
    are replicated across the EP axis (they arrive batch-sharded over
    data/pod); each shard owns E/ep experts, builds a capacity-bounded
    dispatch buffer [E_loc, C, d] with a sorted-rank scatter, runs its
    experts, scatters contributions back weighted by the gates and psums
    over the EP axis.  Capacity overflow drops tokens (standard GShard
    semantics); aux load-balance loss keeps the router honest.

The expert->mesh-axis assignment is the placement decision `core.autoshard`
optimizes -- experts are the closest analogue of the paper's hard blocks
(DESIGN.md SS2).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.runtime import jaxcompat
from repro.models import modules as nn
from repro.sharding import logical


@dataclasses.dataclass(frozen=True)
class MoEArgs:
    d_model: int
    n_routed: int                 # logical routed experts (pre-padding)
    top_k: int
    d_expert: int                 # per-expert FFN width (fine-grained)
    n_shared: int = 0
    n_padded: int = 0             # physical experts incl. padding (>= routed)
    capacity_factor: float = 1.25
    aux_weight: float = 0.01

    @property
    def e_phys(self) -> int:
        return max(self.n_padded, self.n_routed)


def specs(a: MoEArgs) -> Dict[str, nn.ParamSpec]:
    e = a.e_phys
    s: Dict[str, nn.ParamSpec] = {
        "router": nn.dense_spec(a.d_model, e, ("embed", None), scale=0.02),
        "wg": nn.ParamSpec((e, a.d_model, a.d_expert),
                           ("experts", "embed", "expert_mlp"), "normal",
                           1.0 / (a.d_model ** 0.5)),
        "wu": nn.ParamSpec((e, a.d_model, a.d_expert),
                           ("experts", "embed", "expert_mlp"), "normal",
                           1.0 / (a.d_model ** 0.5)),
        "wd": nn.ParamSpec((e, a.d_expert, a.d_model),
                           ("experts", "expert_mlp", "embed"), "normal",
                           1.0 / (a.d_expert ** 0.5)),
    }
    if a.n_shared:
        s["shared"] = {
            "wg": nn.dense_spec(a.d_model, a.n_shared * a.d_expert,
                                ("embed", "mlp")),
            "wu": nn.dense_spec(a.d_model, a.n_shared * a.d_expert,
                                ("embed", "mlp")),
            "wd": nn.dense_spec(a.n_shared * a.d_expert, a.d_model,
                                ("mlp", "embed")),
        }
    return s


def _route(p, a: MoEArgs, xf: jnp.ndarray
           ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """xf: [T, d] -> (top-k indices [T,k], gates [T,k], aux loss)."""
    logits = nn.dense(xf.astype(jnp.float32), p["router"])
    if a.e_phys > a.n_routed:                       # mask padded experts
        pad = jnp.arange(a.e_phys) >= a.n_routed
        logits = jnp.where(pad[None, :], -1e30, logits)
    gates_full = jax.nn.softmax(logits, axis=-1)
    gates, inds = jax.lax.top_k(gates_full, a.top_k)
    gates = gates / jnp.maximum(
        jnp.sum(gates, axis=-1, keepdims=True), 1e-9)
    # Switch-style load balance aux: E * sum_e f_e * p_e
    t = xf.shape[0]
    onehot = jax.nn.one_hot(inds, a.e_phys, dtype=jnp.float32)  # [T,k,E]
    f = jnp.sum(onehot, axis=(0, 1)) / (t * a.top_k)
    pbar = jnp.mean(gates_full, axis=0)
    aux = a.aux_weight * a.n_routed * jnp.sum(f * pbar)
    return inds, gates.astype(xf.dtype), aux


def _expert_ffn(wg, wu, wd, buf: jnp.ndarray) -> jnp.ndarray:
    """buf: [E_loc, C, d] -> [E_loc, C, d] (per-expert SwiGLU)."""
    h = (jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, wg.astype(buf.dtype)))
         * jnp.einsum("ecd,edf->ecf", buf, wu.astype(buf.dtype)))
    return jnp.einsum("ecf,efd->ecd", h, wd.astype(buf.dtype))


def _apply_reference(p, a: MoEArgs, xf: jnp.ndarray
                     ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    inds, gates, aux = _route(p, a, xf)
    # dense: run every expert on every token, gather by gate (tests only)
    h = (jax.nn.silu(jnp.einsum("td,edf->tef", xf, p["wg"].astype(xf.dtype)))
         * jnp.einsum("td,edf->tef", xf, p["wu"].astype(xf.dtype)))
    y_all = jnp.einsum("tef,efd->ted", h, p["wd"].astype(xf.dtype))
    sel = jnp.take_along_axis(y_all, inds[:, :, None], axis=1)  # [T,k,d]
    return jnp.sum(sel * gates[:, :, None], axis=1), aux


def _ranks_by_expert(flat_e: jnp.ndarray, e: int) -> jnp.ndarray:
    """Position of each (token,k) within its expert's arrival order."""
    n = flat_e.shape[0]
    order = jnp.argsort(flat_e, stable=True)
    counts = jnp.zeros(e, jnp.int32).at[flat_e].add(1)
    starts = jnp.cumsum(counts) - counts
    rank_sorted = jnp.arange(n, dtype=jnp.int32) - starts[flat_e[order]]
    return jnp.zeros(n, jnp.int32).at[order].set(rank_sorted)


def _apply_ep(p, a: MoEArgs, xf: jnp.ndarray, mesh, rules
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    ep_axes = rules.get("experts") or "model"
    ep_axes = (ep_axes,) if isinstance(ep_axes, str) else tuple(ep_axes)
    ep = logical._axes_size(mesh, ep_axes)
    e = a.e_phys
    if ep <= 1 or e % ep != 0:
        return _apply_reference(p, a, xf)
    e_loc = e // ep
    t = xf.shape[0]
    # tokens replicated over EP axis; batch axes handled outside
    batch_axes = rules.get("batch")
    bspec = P(batch_axes, None)
    t_loc = t // logical._axes_size(mesh, batch_axes)
    cap = int(a.capacity_factor * a.top_k * t_loc / e) + 1

    def shard_fn(xs, router, wg, wu, wd):
        inds, gates, aux = _route({"router": router}, a, xs)  # [Tl,k]
        flat_e = inds.reshape(-1)
        ranks = _ranks_by_expert(flat_e, e)
        idx = jnp.int32(0)
        for ax in ep_axes:
            idx = idx * mesh.shape[ax] + jax.lax.axis_index(ax)
        e0 = idx * e_loc
        mine = (flat_e >= e0) & (flat_e < e0 + e_loc) & (ranks < cap)
        slot = jnp.where(mine, (flat_e - e0) * cap + ranks, e_loc * cap)
        tok = jnp.repeat(jnp.arange(xs.shape[0]), a.top_k)
        buf = jnp.zeros((e_loc * cap + 1, xs.shape[1]), xs.dtype)
        buf = buf.at[slot].add(xs[tok] * mine[:, None].astype(xs.dtype))
        yb = _expert_ffn(wg, wu, wd,
                         buf[:-1].reshape(e_loc, cap, xs.shape[1]))
        yb = jnp.concatenate(
            [yb.reshape(e_loc * cap, xs.shape[1]),
             jnp.zeros((1, xs.shape[1]), xs.dtype)])
        contrib = yb[slot] * (gates.reshape(-1, 1)
                              * mine[:, None].astype(xs.dtype))
        y = jnp.sum(contrib.reshape(xs.shape[0], a.top_k, -1), axis=1)
        y = jax.lax.psum(y, ep_axes)
        aux = jax.lax.pmean(aux, tuple(mesh.axis_names))  # replicated scalar
        return y, aux

    wspec3 = P(ep_axes if len(ep_axes) > 1 else ep_axes[0], None, None)
    y, aux = jaxcompat.shard_map(
        shard_fn, mesh=mesh,
        in_specs=(bspec, P(None, None), wspec3, wspec3, wspec3),
        out_specs=(bspec, P()),
    )(xf, p["router"], p["wg"], p["wu"], p["wd"])
    return y, aux


def apply(p, a: MoEArgs, x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: [B,S,d] -> (y [B,S,d], aux scalar)."""
    b, s, d = x.shape
    xf = x.reshape(b * s, d)
    ctx = logical.current()
    if ctx is None:
        y, aux = _apply_reference(p, a, xf)
    else:
        y, aux = _apply_ep(p, a, xf, ctx[0], ctx[1])
    y = y.reshape(b, s, d)
    if a.n_shared:
        sh = p["shared"]
        y = y + nn.swiglu(x, sh["wg"], sh["wu"], sh["wd"])
    return logical.constrain(y, "batch", "seq", "embed"), aux
