"""Training loop with checkpoint/restart, elastic recovery, straggler watch.

Single-controller driver used by examples/train_lm.py and launch/train.py.
The loop is deliberately explicit about its fault-tolerance contract:

  on start     : restore latest checkpoint if present (params, opt, step)
  every K steps: async atomic checkpoint (params+opt+data state)
  on failure   : (simulated via `inject_failure_at` or a raised exception)
                 -> remesh_plan -> restore onto the new mesh ->
                 Pipeline.resume with the new shard split -> continue
  every step   : StragglerMonitor.record; mitigation logged when flagged
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional

import jax
import numpy as np

from repro.ckpt import checkpoint
from repro.data.pipeline import DataConfig, Pipeline
from repro.models import transformer as T
from repro.models.transformer import ArchConfig
from repro.runtime.elastic import StragglerMonitor
from repro.train import optimizer as opt
from repro.train.train_step import make_train_step


@dataclasses.dataclass
class TrainerConfig:
    steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: Optional[str] = None
    log_every: int = 10
    n_micro: int = 1
    param_dtype: Any = None        # default fp32
    inject_failure_at: Optional[int] = None   # test hook


class SimulatedFailure(RuntimeError):
    pass


class Trainer:
    def __init__(self, cfg: ArchConfig, ocfg: opt.OptConfig,
                 tcfg: TrainerConfig, data_cfg: DataConfig,
                 seed: int = 0):
        self.cfg, self.ocfg, self.tcfg = cfg, ocfg, tcfg
        self.data_cfg = data_cfg
        self.pipeline = Pipeline(data_cfg)
        key = jax.random.PRNGKey(seed)
        dtype = tcfg.param_dtype or jax.numpy.float32
        self.params = T.init_params(cfg, key, dtype)
        self.opt_state = opt.init(self.params, ocfg.compress_grads)
        self.step = 0
        self.train_step = jax.jit(
            make_train_step(cfg, ocfg, tcfg.n_micro),
            donate_argnums=(0, 1))
        self.monitor = StragglerMonitor()
        self.history: List[Dict[str, float]] = []
        if tcfg.ckpt_dir and checkpoint.latest_steps(tcfg.ckpt_dir):
            self._restore()

    # ------------------------------------------------------------ ckpt

    def _save(self, async_: bool = True):
        if not self.tcfg.ckpt_dir:
            return
        tree = {"params": self.params, "opt": self.opt_state}
        checkpoint.save(self.tcfg.ckpt_dir, self.step, tree,
                        meta={"data": self.pipeline.state(self.step),
                              "arch": self.cfg.name},
                        async_=async_)

    def _restore(self):
        like = {"params": self.params, "opt": self.opt_state}
        tree = checkpoint.restore(self.tcfg.ckpt_dir, like)
        self.params, self.opt_state = tree["params"], tree["opt"]
        self.step = checkpoint.manifest(self.tcfg.ckpt_dir)["step"]
        self.pipeline = Pipeline.resume(
            self.data_cfg, checkpoint.manifest(
                self.tcfg.ckpt_dir)["meta"]["data"])

    # ------------------------------------------------------------ loop

    def run(self) -> List[Dict[str, float]]:
        while self.step < self.tcfg.steps:
            if (self.tcfg.inject_failure_at is not None
                    and self.step == self.tcfg.inject_failure_at):
                self.tcfg.inject_failure_at = None
                raise SimulatedFailure(f"injected at step {self.step}")
            t0 = time.monotonic()
            batch = {k: jax.numpy.asarray(v)
                     for k, v in self.pipeline.batch(self.step).items()}
            self.params, self.opt_state, metrics = self.train_step(
                self.params, self.opt_state, batch)
            self.step += 1
            dt = time.monotonic() - t0
            self.monitor.record(dt)
            if self.step % self.tcfg.log_every == 0 or \
                    self.step == self.tcfg.steps:
                row = {k: float(np.asarray(v)) for k, v in metrics.items()}
                row.update(step=self.step, sec_per_step=dt,
                           straggler=float(self.monitor.straggling()))
                self.history.append(row)
            if self.tcfg.ckpt_every and \
                    self.step % self.tcfg.ckpt_every == 0:
                self._save()
        self._save(async_=False)
        return self.history

    def run_with_recovery(self) -> List[Dict[str, float]]:
        """Run; on failure, restore from the last checkpoint and continue --
        the single-process analogue of a full job restart after remesh."""
        try:
            return self.run()
        except SimulatedFailure:
            if self.tcfg.ckpt_dir and checkpoint.latest_steps(
                    self.tcfg.ckpt_dir):
                self._restore()
            else:                    # no checkpoint yet: restart from 0
                self.step = 0
            return self.run()
