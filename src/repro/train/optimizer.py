"""AdamW from scratch: fp32 master weights, global-norm clip, LR schedules,
and an int8 + error-feedback gradient compressor (distributed-optimization
hook; unit-tested, applied ahead of gradient all-reduce when enabled).

Optimizer state mirrors the param tree; every leaf keeps (master fp32, m, v).
Model params may live in bf16 -- updates always happen on the fp32 master,
the bf16 working copy is re-derived each step (standard mixed precision).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    schedule: str = "cosine"        # cosine | wsd | const
    compress_grads: bool = False    # int8 + error feedback


def schedule_lr(cfg: OptConfig, step: jnp.ndarray) -> jnp.ndarray:
    s = step.astype(jnp.float32)
    warm = jnp.minimum(s / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    if cfg.schedule == "const":
        decay = 1.0
    elif cfg.schedule == "cosine":
        frac = jnp.clip((s - cfg.warmup_steps)
                        / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                        0.0, 1.0)
        decay = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
    elif cfg.schedule == "wsd":     # warmup-stable-decay (10% linear tail)
        tail = int(0.9 * cfg.total_steps)
        decay = jnp.where(
            s < tail, 1.0,
            jnp.clip(1.0 - (s - tail) / max(cfg.total_steps - tail, 1),
                     0.05, 1.0))
    else:
        raise ValueError(cfg.schedule)
    return cfg.lr * warm * decay


def init(params: Any, compress: bool = False) -> Dict[str, Any]:
    # copy=True: with fp32 params, astype would alias the same buffer and
    # break donating params and opt state to the same jitted step

    def f32(p):
        return jnp.array(p, dtype=jnp.float32, copy=True)

    state = {
        "master": jax.tree.map(f32, params),
        "m": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        "v": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        "step": jnp.zeros((), jnp.int32),
    }
    if compress:   # error-feedback residuals only exist when compressing
        state["err"] = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return state


def global_norm(tree: Any) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


# ------------------------------------------------- gradient compression

def quantize_int8(g: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-tensor symmetric int8.  Returns (q, scale)."""
    amax = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12)
    scale = amax / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compress_with_feedback(grads: Any, err: Any) -> Tuple[Any, Any]:
    """int8 round-trip with error feedback: the quantisation residual is
    carried into the next step, making the compression unbiased over time.
    In manual-collective deployments the int8 payload is what crosses the
    wire (4x reduction); under GSPMD the hook documents + tests the math."""

    def one(g, e):
        g = g.astype(jnp.float32) + e
        q, s = quantize_int8(g)
        deq = dequantize_int8(q, s)
        return deq, g - deq

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(err)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (jax.tree.unflatten(treedef, [o[0] for o in outs]),
            jax.tree.unflatten(treedef, [o[1] for o in outs]))


# ------------------------------------------------------------- update

def update(cfg: OptConfig, params: Any, grads: Any, state: Dict[str, Any]
           ) -> Tuple[Any, Dict[str, Any], Dict[str, jnp.ndarray]]:
    step = state["step"] + 1
    grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)

    if cfg.compress_grads:
        grads, new_err = compress_with_feedback(grads, state["err"])

    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12))
    grads = jax.tree.map(lambda g: g * clip, grads)

    lr = schedule_lr(cfg, step)
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(master, g, m, v):
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh = m / bc1
        vh = v / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * master
        return master - lr * delta, m, v

    out = jax.tree.map(upd, state["master"], grads, state["m"], state["v"])
    new_master = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_params = jax.tree.map(
        lambda mst, p: mst.astype(p.dtype), new_master, params)
    new_state = {"master": new_master, "m": new_m, "v": new_v, "step": step}
    if cfg.compress_grads:
        new_state["err"] = new_err
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
