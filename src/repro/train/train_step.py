"""Jitted train step: loss + grads + AdamW, with microbatch accumulation.

`make_train_step` builds the canonical step the dry-run lowers:
params/opt-state shardings come from the logical rules, the batch is
data-sharded, donation keeps params/opt-state in place.  Microbatching
(grad accumulation via lax.scan over batch slices) trades activation memory
for steps -- one of the hillclimb levers in EXPERIMENTS.md SSPerf.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.models import transformer as T
from repro.models.transformer import ArchConfig
from repro.train import optimizer as opt


def make_train_step(cfg: ArchConfig, ocfg: opt.OptConfig,
                    n_micro: int = 1) -> Callable:
    """Returns train_step(params, opt_state, batch) -> (params', opt', metrics)."""

    def grads_of(params, batch):
        (loss, metrics), grads = jax.value_and_grad(
            T.loss_fn, has_aux=True)(params, cfg, batch)
        return loss, metrics, grads

    def train_step(params, opt_state, batch):
        if n_micro == 1:
            loss, metrics, grads = grads_of(params, batch)
        else:
            def micro(carry, mb):
                acc = carry
                loss, metrics, g = grads_of(params, mb)
                acc = jax.tree.map(jnp.add, acc, g)
                return acc, (loss, metrics)

            mb = jax.tree.map(
                lambda a: a.reshape((n_micro, a.shape[0] // n_micro)
                                    + a.shape[1:]), batch)
            # zeros_like keeps the param's sharding -> the f32 accumulator
            # stays FSDP/TP-sharded instead of replicating (critical at 123B)
            zero = jax.tree.map(
                lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
            gsum, (losses, metricss) = jax.lax.scan(micro, zero, mb)
            grads = jax.tree.map(lambda g: g / n_micro, gsum)
            loss = jnp.mean(losses)
            metrics = jax.tree.map(jnp.mean, metricss)
        new_params, new_opt, onorm = opt.update(ocfg, params, grads,
                                                opt_state)
        metrics = dict(metrics, loss=loss, **onorm)
        return new_params, new_opt, metrics

    return train_step


def make_eval_step(cfg: ArchConfig) -> Callable:
    def eval_step(params, batch):
        loss, metrics = T.loss_fn(params, cfg, batch)
        return dict(metrics, loss=loss)

    return eval_step
