"""Compile-latency control: persistent compilation cache + compile meter.

The bench says XLA compilation -- not evolution steps -- is the serving
tail-latency killer: a cold `PlacementService` blocks tens of seconds on
backend compiles before its first generation, and every geometric-ladder
`grow()` or lazily-created scheduler pool repeats the bill.  This module is
the runtime half of the fix (the serving half is `serve.prewarm`):

  * **persistent cache** -- `enable(cache_dir)` turns on jax's persistent
    compilation cache rooted at `cache_dir` with thresholds zeroed, so
    EVERY program the service compiles (step, init, warm-init, fill, at
    every slot-ladder size) is serialized to disk.  A restarted process --
    or a CI runner restoring the directory -- deserializes instead of
    recompiling: jax keys entries on the lowered computation plus its own
    jax/XLA-version and device-topology salt, so the per-pool-signature
    keying the scheduler needs falls out for free (a different `PoolKey`
    lowers to a different program; a jax upgrade or device-count change
    can never serve a stale binary).
  * **compile meter** -- a process-wide counter/timer fed by
    `jax.monitoring` events: total backend-compile requests, real compile
    seconds, and persistent-cache hits/misses.  `recompiles` is the number
    of *real* XLA compiles (requests not answered by the cache), the
    quantity the CI compile budget pins at zero for a warm start.
    `measure()` scopes the count to the calling thread, which is how
    `PlacementService` separates *blocking* compiles (in the stepping
    loop's thread) from background prewarm compiles.

Nothing here is load-bearing for results: the cache and the meter change
when compilation happens, never what the compiled programs produce, and
with neither enabled the service is bitwise the pre-PR code path.
"""
from __future__ import annotations

import contextlib
import hashlib
import os
import threading
import warnings
from typing import Any, Dict, Optional

import jax

# jax.monitoring event keys (jax 0.4.x).  A jax upgrade that renames them
# degrades the meter to "nothing observed" -- callers treat 0-compiles-seen
# with `events_seen == 0` as "meter unavailable", never as "no compiles".
_BACKEND_COMPILE = "/jax/core/compile/backend_compile_duration"
_CACHE_HIT = "/jax/compilation_cache/cache_hits"
_CACHE_MISS = "/jax/compilation_cache/cache_misses"


class _Scope:
    """One `measure()` window: compiles observed on the opening thread."""

    __slots__ = ("compiles", "secs")

    def __init__(self) -> None:
        self.compiles = 0
        self.secs = 0.0


class CompileMeter:
    """Process-wide compile counter/timer (jax.monitoring listeners).

    Counters:
      * `compiles`      -- backend-compile *requests* (cache-served ones
        included: jax fires the compile event either way),
      * `compile_secs`  -- wall seconds inside those requests (a cache hit
        costs milliseconds of deserialization, a miss costs the real
        compile),
      * `cache_hits` / `cache_misses` -- persistent-cache outcomes (only
        fire while the cache is enabled),
      * `recompiles`    -- real XLA compiles: `compiles - cache_hits`,
        uniform whether or not the persistent cache is on.

    `measure()` additionally scopes compile counts to the calling thread
    for the duration of a `with` block, so a service can attribute
    compiles to the exact blocking entry point (submit/step/grow) that
    triggered them while a background prewarm thread compiles freely.
    """

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._installed = False
        self.compiles = 0
        self.compile_secs = 0.0
        self.cache_hits = 0
        self.cache_misses = 0
        self.events_seen = 0
        self._scopes: Dict[int, list] = {}     # thread id -> open scope stack

    # ------------------------------------------------------------ install

    def install(self) -> "CompileMeter":
        """Register the monitoring listeners (idempotent; listeners are
        process-permanent, so there is exactly one global meter)."""
        with self._lock:
            if self._installed:
                return self
            self._installed = True
        try:
            from jax import monitoring
            monitoring.register_event_listener(self._on_event)
            monitoring.register_event_duration_secs_listener(self._on_dur)
        except Exception as e:                       # pragma: no cover
            warnings.warn(f"compile meter unavailable ({e}); compile "
                          "counts will read 0", stacklevel=2)
        return self

    def _on_event(self, name: str, **kw: Any) -> None:
        if name == _CACHE_HIT:
            with self._lock:
                self.cache_hits += 1
                self.events_seen += 1
        elif name == _CACHE_MISS:
            with self._lock:
                self.cache_misses += 1
                self.events_seen += 1

    def _on_dur(self, name: str, secs: float, **kw: Any) -> None:
        if name != _BACKEND_COMPILE:
            return
        tid = threading.get_ident()
        with self._lock:
            self.compiles += 1
            self.compile_secs += secs
            self.events_seen += 1
            for scope in self._scopes.get(tid, ()):
                scope.compiles += 1
                scope.secs += secs

    # ------------------------------------------------------------ reading

    @property
    def recompiles(self) -> int:
        """Real XLA compiles (requests the persistent cache did not
        answer).  With the cache off no hit events fire, so this equals
        `compiles`; with it on it equals `cache_misses`."""
        return self.compiles - self.cache_hits

    @contextlib.contextmanager
    def measure(self):
        """Scope compile counting to the calling thread::

            with meter.measure() as m:
                jitted_fn(args)          # may compile
            m.compiles, m.secs           # compiles on THIS thread only
        """
        scope = _Scope()
        tid = threading.get_ident()
        with self._lock:
            self._scopes.setdefault(tid, []).append(scope)
        try:
            yield scope
        finally:
            with self._lock:
                self._scopes[tid].remove(scope)
                if not self._scopes[tid]:
                    del self._scopes[tid]

    def stats(self) -> Dict[str, Any]:
        with self._lock:
            return {
                "compiles": self.compiles,
                "recompiles": self.recompiles,
                "cache_hits": self.cache_hits,
                "cache_misses": self.cache_misses,
                "compile_secs": round(self.compile_secs, 3),
                "events_seen": self.events_seen,
                "persistent_cache_dir": enabled_dir(),
            }

    def telemetry_samples(self):
        """The meter as `(name, kind, help, value)` rows for the metrics
        registry's collect walk (`runtime.telemetry` registers a collector
        over this), so compile counters land in the same Prometheus scrape
        as job counters instead of living in a side dict."""
        with self._lock:
            return [
                ("repro_compiles_total", "counter",
                 "Backend compile requests (cache-served included)",
                 float(self.compiles)),
                ("repro_recompiles_total", "counter",
                 "Real XLA compiles (requests not answered by the "
                 "persistent cache)", float(self.recompiles)),
                ("repro_compile_seconds_total", "counter",
                 "Wall seconds inside backend compile requests",
                 round(self.compile_secs, 6)),
                ("repro_compile_cache_hits_total", "counter",
                 "Persistent compilation cache hits",
                 float(self.cache_hits)),
                ("repro_compile_cache_misses_total", "counter",
                 "Persistent compilation cache misses",
                 float(self.cache_misses)),
            ]


_METER = CompileMeter()


def meter() -> CompileMeter:
    """The process-global compile meter (listeners installed lazily by the
    first `install()`; `PlacementService` installs on construction)."""
    return _METER


# --------------------------------------------------------------- enabling

_ENABLED_DIR: Optional[str] = None


def enable(cache_dir: str) -> str:
    """Enable jax's persistent compilation cache rooted at `cache_dir`.

    Thresholds are zeroed (`min_entry_size`/`min_compile_time`) so every
    service program persists -- the pool-shaped programs are individually
    small but collectively the whole cold-start bill.  Safe to call more
    than once; the last directory wins.  Returns the directory.
    """
    global _ENABLED_DIR
    os.makedirs(cache_dir, exist_ok=True)
    try:
        jax.config.update("jax_compilation_cache_dir", cache_dir)
        jax.config.update("jax_persistent_cache_min_entry_size_bytes", -1)
        jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.0)
    except (AttributeError, ValueError) as e:        # pragma: no cover
        warnings.warn(f"persistent compilation cache unavailable on this "
                      f"jax ({e}); continuing without it", stacklevel=2)
        return cache_dir
    _reset_jax_cache_latch()
    _ENABLED_DIR = cache_dir
    meter().install()
    return cache_dir


def _reset_jax_cache_latch() -> None:
    """jax latches its "is the cache used?" decision at the FIRST compile
    of the process; a process that compiled anything before `enable()`
    (imports with eager ops, a test suite, a service enabling mid-flight)
    would silently never persist.  `reset_cache()` un-latches it so the
    new directory takes effect; private API, so a jax that moved it just
    degrades to the latch's old behaviour (enable-before-first-compile
    still works)."""
    try:
        from jax._src import compilation_cache
        compilation_cache.reset_cache()
    except Exception:                                # pragma: no cover
        pass


def disable() -> None:
    """Turn the persistent cache back off (tests; the listener-based meter
    stays installed -- listeners are process-permanent)."""
    global _ENABLED_DIR
    try:
        jax.config.update("jax_compilation_cache_dir", None)
    except (AttributeError, ValueError):             # pragma: no cover
        pass
    _reset_jax_cache_latch()
    _ENABLED_DIR = None


def enabled_dir() -> Optional[str]:
    """The active persistent-cache directory, or None when disabled."""
    return _ENABLED_DIR


def maybe_enable_from_env(flag_dir: Optional[str] = None) -> Optional[str]:
    """`enable()` from an explicit flag value or the
    `REPRO_COMPILE_CACHE_DIR` environment variable; no-op when neither is
    set (entry points call this so `--compile-cache-dir` and the env var
    behave identically)."""
    cache_dir = flag_dir or os.environ.get("REPRO_COMPILE_CACHE_DIR")
    return enable(cache_dir) if cache_dir else None


# ------------------------------------------------------------------- salt

def cache_salt() -> str:
    """Human-readable jax-version/backend/device-count salt.

    jax already folds all of this into its persistent-cache keys; this
    string exists for the layers *around* the cache -- CI `actions/cache`
    keys and prewarm bookkeeping -- so they partition storage the same way
    the entries inside it are partitioned."""
    return (f"jax{jax.__version__}-{jax.default_backend()}"
            f"-d{jax.device_count()}")


def pool_token(pool_key: Any) -> str:
    """Stable short token for one pool signature under the current salt
    (prewarm bookkeeping / stats labels; not a jax cache key)."""
    text = repr((pool_key, cache_salt()))
    return hashlib.sha1(text.encode()).hexdigest()[:16]
