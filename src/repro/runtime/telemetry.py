"""Process-wide metrics registry: counters, gauges, fixed-bucket histograms.

The serving stack so far had exactly two observability surfaces: per-layer
`stats()` snapshot dicts and the PR 6 compile meter.  Neither answers a
live operational question ("is this pool still converging?", "what is the
p99 submit->champion latency *right now*?") without caller code polling
and diffing dicts.  This module is the metrics half of the observability
layer (`serve.tracing` is the span/event half):

  * **registry** -- one process-global `MetricsRegistry` (thread-safe)
    holding named `Counter` / `Gauge` / `Histogram` instruments, each
    optionally labelled (e.g. one `repro_pool_best_metric` gauge with a
    `pool` label per pool).  Instruments are cheap host-side arithmetic
    under one lock; the serving layers record into them unconditionally
    -- the cost is nanoseconds next to a jitted step -- and *exporters*
    are what the config flags gate.
  * **compile meter folded in** -- the registry's collect walk includes a
    collector reading `runtime.compile_cache.meter()`, so compile
    requests / real recompiles / persistent-cache hits appear in the same
    Prometheus scrape as job counters instead of living beside them in a
    separate dict.
  * **Prometheus text exposition** -- `prometheus_text()` renders the
    0.0.4 text format (HELP/TYPE comments, cumulative `_bucket{le=}`
    histogram series, `_sum`/`_count`); `start_http_server(port)` serves
    it from a stdlib `ThreadingHTTPServer` on `/metrics` (port 0 binds an
    ephemeral port; the bound port is returned).

Nothing here is load-bearing for results: instruments only *observe* the
host-side serving loop.  jitted programs never read them.
"""
from __future__ import annotations

import threading
from typing import Any, Callable, Dict, Iterable, List, Optional, Tuple

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "registry",
    "start_http_server", "DEFAULT_LATENCY_BUCKETS_MS",
]

# shared default for latency-shaped histograms (milliseconds): sub-ms
# cache hits through multi-minute cold compiles
DEFAULT_LATENCY_BUCKETS_MS = (
    1.0, 5.0, 10.0, 25.0, 50.0, 100.0, 250.0, 500.0,
    1000.0, 2500.0, 5000.0, 10000.0, 30000.0, 60000.0)

LabelKey = Tuple[Tuple[str, str], ...]


def _label_key(labels: Dict[str, Any]) -> LabelKey:
    return tuple(sorted((k, str(v)) for k, v in labels.items()))


def _escape(value: str) -> str:
    return (value.replace("\\", r"\\").replace("\n", r"\n")
            .replace('"', r'\"'))


def _fmt_labels(key: LabelKey, extra: str = "") -> str:
    parts = [f'{k}="{_escape(v)}"' for k, v in key]
    if extra:
        parts.append(extra)
    return "{" + ",".join(parts) + "}" if parts else ""


class _Instrument:
    """Shared base: a named instrument with per-label-set samples."""

    kind = "untyped"

    def __init__(self, name: str, help: str = "") -> None:
        self.name = name
        self.help = help
        self._lock = threading.Lock()
        self._samples: Dict[LabelKey, Any] = {}

    def samples(self) -> Dict[LabelKey, Any]:
        with self._lock:
            return dict(self._samples)

    def expose(self) -> List[str]:
        lines = [f"# HELP {self.name} {_escape(self.help)}",
                 f"# TYPE {self.name} {self.kind}"]
        for key, value in sorted(self.samples().items()):
            lines.append(f"{self.name}{_fmt_labels(key)} {_fmt_num(value)}")
        return lines


def _fmt_num(v: float) -> str:
    f = float(v)
    return repr(int(f)) if f == int(f) and abs(f) < 1e15 else repr(f)


class Counter(_Instrument):
    """Monotone counter; `inc(n, **labels)` (n must be >= 0)."""

    kind = "counter"

    def inc(self, n: float = 1.0, **labels: Any) -> None:
        if n < 0:
            raise ValueError(f"counter {self.name} cannot decrease ({n})")
        key = _label_key(labels)
        with self._lock:
            self._samples[key] = self._samples.get(key, 0.0) + n

    def value(self, **labels: Any) -> float:
        with self._lock:
            return float(self._samples.get(_label_key(labels), 0.0))


class Gauge(_Instrument):
    """Point-in-time value; `set(v, **labels)` / `inc` / `dec`."""

    kind = "gauge"

    def set(self, v: float, **labels: Any) -> None:
        with self._lock:
            self._samples[_label_key(labels)] = float(v)

    def inc(self, n: float = 1.0, **labels: Any) -> None:
        key = _label_key(labels)
        with self._lock:
            self._samples[key] = self._samples.get(key, 0.0) + n

    def dec(self, n: float = 1.0, **labels: Any) -> None:
        self.inc(-n, **labels)

    def value(self, **labels: Any) -> float:
        with self._lock:
            return float(self._samples.get(_label_key(labels), 0.0))


class _HistState:
    __slots__ = ("counts", "overflow", "sum", "count")

    def __init__(self, n_buckets: int) -> None:
        self.counts = [0] * n_buckets      # per-bucket, non-cumulative
        self.overflow = 0                  # > last bound (+Inf bucket)
        self.sum = 0.0
        self.count = 0


class Histogram(_Instrument):
    """Fixed-bucket histogram: `observe(v, **labels)`.

    Buckets are upper bounds (ascending); values above the last bound land
    in the implicit +Inf bucket.  Exposition is cumulative per Prometheus
    convention; `to_dict()` embeds the non-cumulative counts into
    `stats()` payloads.  Standalone use (outside any registry) is fine --
    the serve layers keep per-instance histograms for their own `stats()`
    and mirror observations into the registry-global instrument.
    """

    kind = "histogram"

    def __init__(self, name: str, help: str = "",
                 buckets: Iterable[float] = DEFAULT_LATENCY_BUCKETS_MS
                 ) -> None:
        super().__init__(name, help)
        self.buckets = tuple(float(b) for b in buckets)
        if not self.buckets or any(a >= b for a, b in zip(self.buckets,
                                                          self.buckets[1:])):
            raise ValueError("buckets must be non-empty and ascending")

    def observe(self, v: float, **labels: Any) -> None:
        v = float(v)
        key = _label_key(labels)
        with self._lock:
            st = self._samples.get(key)
            if st is None:
                st = self._samples[key] = _HistState(len(self.buckets))
            st.sum += v
            st.count += 1
            for i, bound in enumerate(self.buckets):
                if v <= bound:
                    st.counts[i] += 1
                    break
            else:
                st.overflow += 1

    def to_dict(self, **labels: Any) -> Dict[str, Any]:
        """JSON-able snapshot for one label set (the `stats()` embedding):
        non-cumulative bucket counts + overflow + sum/count."""
        with self._lock:
            st = self._samples.get(_label_key(labels))
            if st is None:
                return {"buckets": list(self.buckets),
                        "counts": [0] * len(self.buckets),
                        "overflow": 0, "count": 0, "sum": 0.0}
            return {"buckets": list(self.buckets),
                    "counts": list(st.counts),
                    "overflow": st.overflow,
                    "count": st.count,
                    "sum": round(st.sum, 3)}

    def expose(self) -> List[str]:
        lines = [f"# HELP {self.name} {_escape(self.help)}",
                 f"# TYPE {self.name} histogram"]
        for key, st in sorted(self.samples().items(),
                              key=lambda kv: kv[0]):
            cum = 0
            for bound, n in zip(self.buckets, st.counts):
                cum += n
                le = 'le="%s"' % _fmt_num(bound)
                lines.append(
                    f"{self.name}_bucket{_fmt_labels(key, le)} {cum}")
            inf = 'le="+Inf"'
            lines.append(f"{self.name}_bucket"
                         f"{_fmt_labels(key, inf)} {st.count}")
            lines.append(f"{self.name}_sum{_fmt_labels(key)} "
                         f"{_fmt_num(st.sum)}")
            lines.append(f"{self.name}_count{_fmt_labels(key)} {st.count}")
        return lines


# collector: () -> iterable of (name, kind, help, [(labels_dict, value)])
Collector = Callable[[], Iterable[Tuple[str, str, str,
                                        List[Tuple[Dict[str, str],
                                                   float]]]]]


class MetricsRegistry:
    """Named instruments + collect-time collectors, one lock, no deps."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._instruments: Dict[str, _Instrument] = {}
        self._collectors: List[Collector] = []

    def _get(self, cls, name: str, help: str, **kw: Any):
        with self._lock:
            inst = self._instruments.get(name)
            if inst is None:
                inst = self._instruments[name] = cls(name, help, **kw)
            elif not isinstance(inst, cls):
                raise TypeError(f"metric {name!r} already registered as "
                                f"{inst.kind}, not {cls.kind}")
            return inst

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get(Gauge, name, help)

    def histogram(self, name: str, help: str = "",
                  buckets: Iterable[float] = DEFAULT_LATENCY_BUCKETS_MS
                  ) -> Histogram:
        return self._get(Histogram, name, help, buckets=buckets)

    def register_collector(self, fn: Collector) -> None:
        with self._lock:
            if fn not in self._collectors:
                self._collectors.append(fn)

    def prometheus_text(self) -> str:
        """The registry in Prometheus text exposition format 0.0.4."""
        with self._lock:
            instruments = list(self._instruments.values())
            collectors = list(self._collectors)
        lines: List[str] = []
        for inst in sorted(instruments, key=lambda i: i.name):
            lines.extend(inst.expose())
        for fn in collectors:
            for name, kind, help, samples in fn():
                lines.append(f"# HELP {name} {_escape(help)}")
                lines.append(f"# TYPE {name} {kind}")
                for labels, value in samples:
                    lines.append(f"{name}{_fmt_labels(_label_key(labels))}"
                                 f" {_fmt_num(value)}")
        return "\n".join(lines) + "\n"

    def snapshot(self) -> Dict[str, Any]:
        """Plain-dict view (tests / debugging): name -> {labels: value}."""
        with self._lock:
            instruments = list(self._instruments.values())
        out: Dict[str, Any] = {}
        for inst in instruments:
            out[inst.name] = {
                _fmt_labels(key) or "": (v.count if isinstance(v, _HistState)
                                         else v)
                for key, v in inst.samples().items()}
        return out


def _compile_meter_collector():
    """Fold the PR 6 compile meter into the scrape (the registry is where
    compile observability lives now; `CompileMeter` stays the jax-facing
    listener)."""
    from repro.runtime import compile_cache
    for name, kind, help, value in compile_cache.meter().telemetry_samples():
        yield name, kind, help, [({}, value)]


_REGISTRY = MetricsRegistry()
_REGISTRY.register_collector(_compile_meter_collector)


def registry() -> MetricsRegistry:
    """The process-global registry (serving layers all record into it)."""
    return _REGISTRY


# -------------------------------------------------------------- HTTP server

def start_http_server(port: int = 0,
                      reg: Optional[MetricsRegistry] = None,
                      host: str = "127.0.0.1"):
    """Serve `reg.prometheus_text()` on `http://host:port/metrics` from a
    stdlib threading HTTP server (daemon thread).  `port=0` binds an
    ephemeral port.  Returns `(server, bound_port)`; `server.shutdown()`
    stops it."""
    import http.server

    reg = reg or registry()

    class _Handler(http.server.BaseHTTPRequestHandler):
        def do_GET(self):                                  # noqa: N802
            if self.path.split("?")[0] not in ("/", "/metrics"):
                self.send_error(404)
                return
            body = reg.prometheus_text().encode()
            self.send_response(200)
            self.send_header("Content-Type",
                             "text/plain; version=0.0.4; charset=utf-8")
            self.send_header("Content-Length", str(len(body)))
            self.end_headers()
            self.wfile.write(body)

        def log_message(self, *a: Any) -> None:            # silence 200s
            pass

    server = http.server.ThreadingHTTPServer((host, port), _Handler)
    server.daemon_threads = True
    thread = threading.Thread(target=server.serve_forever,
                              name="metrics-http", daemon=True)
    thread.start()
    return server, server.server_address[1]
