"""Elastic runtime: failure detection, remesh planning, straggler policy.

Single-controller control plane for 1000+-node posture:

  * `FailureDetector` -- heartbeat registry with timeout; in production the
    heartbeats are RPC pings, here they are clocked injections (tests drive
    time explicitly, trainer hooks call `beat`).
  * `remesh_plan` -- given surviving host count and the current (pod, data,
    model) preference, pick the largest legal mesh: model parallelism is
    preserved (weights must still divide), the data axis absorbs the loss,
    stragglers/failures therefore only shrink global batch.
  * `StragglerMonitor` -- per-step latency ring; flags a straggler regime
    (p95/median ratio) and recommends the mitigation the trainer applies
    (skip-and-backfill for EA islands / microbatch rebalance for SGD).

Recovery path (exercised in tests/test_elastic.py): detector fires ->
remesh_plan -> checkpoint.restore(shardings on the new mesh) ->
Pipeline.resume(new shard split) -> continue at the same step.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Deque, Dict, List, Optional, Tuple


class FailureDetector:
    def __init__(self, hosts: List[str], timeout_s: float = 10.0):
        self.timeout = timeout_s
        self.last: Dict[str, float] = {h: 0.0 for h in hosts}

    def beat(self, host: str, now: Optional[float] = None) -> None:
        self.last[host] = time.monotonic() if now is None else now

    def dead(self, now: Optional[float] = None) -> List[str]:
        t = time.monotonic() if now is None else now
        return [h for h, ts in self.last.items() if t - ts > self.timeout]

    def alive(self, now: Optional[float] = None) -> List[str]:
        d = set(self.dead(now))
        return [h for h in self.last if h not in d]


@dataclasses.dataclass(frozen=True)
class MeshPlan:
    shape: Tuple[int, ...]
    axes: Tuple[str, ...]
    dropped_hosts: int


def remesh_plan(n_alive_chips: int, model_parallel: int,
                pods: int = 1) -> MeshPlan:
    """Largest (pod, data, model) mesh with `model_parallel` preserved.

    Model parallelism is a *correctness* constraint (weight shards must
    divide); data parallelism absorbs the capacity loss -- failures shrink
    the global batch, never the layout.
    """
    if n_alive_chips < model_parallel:
        raise RuntimeError(
            f"cannot keep model_parallel={model_parallel} with "
            f"{n_alive_chips} chips")
    per_pod = n_alive_chips // max(pods, 1)
    data = max(per_pod // model_parallel, 1)
    used = pods * data * model_parallel
    if pods > 1:
        return MeshPlan((pods, data, model_parallel),
                        ("pod", "data", "model"),
                        n_alive_chips - used)
    return MeshPlan((data, model_parallel), ("data", "model"),
                    n_alive_chips - used)


class StragglerMonitor:
    """Detects a straggler regime from step latencies (p95/median ratio)."""

    def __init__(self, window: int = 50, ratio: float = 2.0):
        self.durations: Deque[float] = deque(maxlen=window)
        self.ratio = ratio

    def record(self, seconds: float) -> None:
        self.durations.append(seconds)

    def straggling(self) -> bool:
        if len(self.durations) < 10:
            return False
        xs = sorted(self.durations)
        med = xs[len(xs) // 2]
        p95 = xs[int(0.95 * (len(xs) - 1))]
        return med > 0 and (p95 / med) > self.ratio

    def recommendation(self) -> str:
        if not self.straggling():
            return "none"
        # EA islands: lengthen migration period (bounded staleness).
        # SGD: shrink per-host microbatch + backup-step the slow host.
        return "rebalance"
