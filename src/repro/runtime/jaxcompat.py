"""Version-spanning shims for jax APIs that moved between releases.

The container pins jax 0.4.x while parts of this codebase were written
against the current API.  The call sites that drifted:

  * `shard_map`: top-level `jax.shard_map(..., check_vma=)` now,
    `jax.experimental.shard_map.shard_map(..., check_rep=)` on 0.4.x.
  * `jax.make_mesh`: grew an `axis_types=` kwarg (`jax.sharding.AxisType`)
    after 0.4.x; plain construction is equivalent for our Auto meshes.
  * axis-name collectives: `jax.lax.axis_size` only exists on newer jax
    (0.4.x spells it `psum(1, axis)`), and the blessed import path for the
    others has moved before.  `axis_index` / `axis_size` / `psum` /
    `ppermute` / `all_gather` below are the uniform axis-name API every
    shard_mapped caller (core.islands ring migration, evolve.run_islands,
    launch.mesh) consumes, plus `ring_perm` for the canonical
    champion-ring permutation.

Route every mesh/shard_map/collective use through here so a jax upgrade
is a one-file change.
"""
from __future__ import annotations

from typing import List, Tuple

import jax

if hasattr(jax, "shard_map"):
    def shard_map(f, mesh, in_specs, out_specs):
        return jax.shard_map(f, mesh=mesh, check_vma=False,
                             in_specs=in_specs, out_specs=out_specs)
else:                                                  # jax <= 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map_04

    def shard_map(f, mesh, in_specs, out_specs):
        return _shard_map_04(f, mesh=mesh, check_rep=False,
                             in_specs=in_specs, out_specs=out_specs)


_HAS_AXIS_TYPES = hasattr(jax.sharding, "AxisType")


def make_mesh(shape, names) -> jax.sharding.Mesh:
    """`jax.make_mesh` with Auto axis types where the API supports them.

    Resolved once at import (like the shard_map shim above) so a caller's
    own TypeError is never masked by a version-probe retry.
    """
    if _HAS_AXIS_TYPES:
        return jax.make_mesh(
            shape, names,
            axis_types=(jax.sharding.AxisType.Auto,) * len(names))
    return jax.make_mesh(shape, names)                 # jax <= 0.4.x


# ------------------------------------------------ axis-name collectives
#
# Thin, version-stable wrappers: callers never import jax.lax collectives
# directly, so a future rename (like shard_map's) stays a one-file change.

def axis_index(axis: str) -> jax.Array:
    """This shard's index along a shard_map/pmap axis name."""
    return jax.lax.axis_index(axis)


if hasattr(jax.lax, "axis_size"):
    def axis_size(axis: str) -> int:
        """Number of shards along an axis name."""
        return jax.lax.axis_size(axis)
else:                                                  # jax <= 0.4.x
    def axis_size(axis: str) -> int:
        """Number of shards along an axis name (0.4.x spelling)."""
        return jax.lax.psum(1, axis_name=axis)


def psum(x, axis: str):
    """Sum `x` across all shards of an axis name."""
    return jax.lax.psum(x, axis_name=axis)


def ppermute(x, axis: str, perm: List[Tuple[int, int]]):
    """Send `x` along (source, destination) pairs over an axis name."""
    return jax.lax.ppermute(x, axis_name=axis, perm=perm)


def all_gather(x, axis, tiled: bool = False):
    """Gather `x` from every shard along one axis name (or a tuple of
    names, flattened into one leading dim)."""
    return jax.lax.all_gather(x, axis, tiled=tiled)


def ring_perm(n: int) -> List[Tuple[int, int]]:
    """The champion-ring permutation: shard i sends to shard (i+1) % n,
    so every receiver adopts its *left* neighbour's payload -- the same
    direction as `jnp.roll(x, 1, axis=0)` on an unsharded stack."""
    return [(i, (i + 1) % n) for i in range(n)]
