"""Version-spanning shims for jax APIs that moved between releases.

The container pins jax 0.4.x while parts of this codebase were written
against the current API.  Two call sites drifted:

  * `shard_map`: top-level `jax.shard_map(..., check_vma=)` now,
    `jax.experimental.shard_map.shard_map(..., check_rep=)` on 0.4.x.
  * `jax.make_mesh`: grew an `axis_types=` kwarg (`jax.sharding.AxisType`)
    after 0.4.x; plain construction is equivalent for our Auto meshes.

Route every mesh/shard_map use through here so a jax upgrade is a
one-file change.
"""
from __future__ import annotations

import jax

if hasattr(jax, "shard_map"):
    def shard_map(f, mesh, in_specs, out_specs):
        return jax.shard_map(f, mesh=mesh, check_vma=False,
                             in_specs=in_specs, out_specs=out_specs)
else:                                                  # jax <= 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map_04

    def shard_map(f, mesh, in_specs, out_specs):
        return _shard_map_04(f, mesh=mesh, check_rep=False,
                             in_specs=in_specs, out_specs=out_specs)


_HAS_AXIS_TYPES = hasattr(jax.sharding, "AxisType")


def make_mesh(shape, names) -> jax.sharding.Mesh:
    """`jax.make_mesh` with Auto axis types where the API supports them.

    Resolved once at import (like the shard_map shim above) so a caller's
    own TypeError is never masked by a version-probe retry.
    """
    if _HAS_AXIS_TYPES:
        return jax.make_mesh(
            shape, names,
            axis_types=(jax.sharding.AxisType.Auto,) * len(names))
    return jax.make_mesh(shape, names)                 # jax <= 0.4.x
