"""jamba-v0.1-52b [arXiv:2403.19887; hf]: Mamba+attention 1:7 interleave,
MoE 16e top-2 on alternate layers.  SSM layers keep O(1) decode state ->
runs long_500k."""
from repro.models.transformer import ArchConfig

CONFIG = ArchConfig(
    name="jamba-v0.1-52b", family="hybrid",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_head=128,
    d_ff=14336, vocab=65536,
    attn_every=8, moe_every=2, n_routed=16, top_k=2, d_expert=14336,
    n_padded=16, d_state=16,
    subquadratic=True,
)
