"""deepseek-moe-16b [arXiv:2401.06066; hf]: fine-grained MoE, 2 shared +
64 routed top-6 experts per layer."""
from repro.models.transformer import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-moe-16b", family="moe",
    n_layers=28, d_model=2048, n_heads=16, n_kv_heads=16, d_head=128,
    d_ff=1408, vocab=102400,
    moe_every=1, n_routed=64, top_k=6, n_shared=2, d_expert=1408,
    n_padded=64,
)
