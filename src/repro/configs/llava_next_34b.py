"""llava-next-34b [hf:llava-hf line]: VLM backbone; anyres vision tower is a
stub supplying patch embeddings (models/stubs.py).  56 heads do not divide
the 16-wide model axis -> the rules engine shards the flattened head dim
(56*128 = 7168 divides) and lets sequence sharding carry attention."""
from repro.models.transformer import ArchConfig

CONFIG = ArchConfig(
    name="llava-next-34b", family="vlm",
    n_layers=60, d_model=7168, n_heads=56, n_kv_heads=8, d_head=128,
    d_ff=20480, vocab=64000,
    frontend="vision", n_frontend_tokens=576,
)
