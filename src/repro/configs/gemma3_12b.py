"""gemma3-12b [hf:google/gemma-3 family]: 5:1 local:global attention,
sliding window 1024, 128k context.  Sub-quadratic locals -> runs long_500k
(the 1-in-6 global layers hold full KV; decode stays linear)."""
from repro.models.transformer import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-12b", family="dense",
    n_layers=48, d_model=3840, n_heads=16, n_kv_heads=8, d_head=256,
    d_ff=15360, vocab=262144,
    window=1024, local_ratio=5, rope_theta=1_000_000.0,
    subquadratic=True,
)
