"""Architecture registry (--arch <id>), shape registry, input specs.

Each assigned architecture lives in its own module (src/repro/configs/<id>.py)
exporting CONFIG; this module aggregates them, defines the four assigned
input shapes, builds reduced smoke-test variants, and produces the
ShapeDtypeStruct input trees the dry-run lowers against (no allocation).
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.models import stubs
from repro.models.transformer import ArchConfig

ARCHS = (
    "deepseek-moe-16b", "qwen2-moe-a2.7b", "gemma3-12b", "yi-6b",
    "mistral-large-123b", "granite-8b", "llava-next-34b", "jamba-v0.1-52b",
    "musicgen-large", "rwkv6-1.6b",
)

_MODULE = {a: a.replace("-", "_").replace(".", "_") for a in ARCHS}


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # train | prefill | decode


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def list_archs():
    return ARCHS


def get_arch(name: str) -> ArchConfig:
    if name == "vu_systolic":      # the paper's own design, for EA dry-runs
        raise KeyError("vu_systolic is a placement config; use repro.fpga")
    mod = importlib.import_module(f"repro.configs.{_MODULE[name]}")
    return mod.CONFIG


def shape_applicable(cfg: ArchConfig, shape: str) -> bool:
    """long_500k needs sub-quadratic attention (DESIGN.md skip table)."""
    if shape == "long_500k":
        return cfg.subquadratic
    return True


def get_reduced(name: str) -> ArchConfig:
    """Family-preserving smoke-test config: tiny widths/depths, same block
    pattern, same MoE/hybrid/ssm structure."""
    c = get_arch(name)
    period = c.period
    n_heads = min(c.n_heads, 4)
    kv = max(1, min(c.n_kv_heads, n_heads))
    while n_heads % kv:
        kv -= 1
    return dataclasses.replace(
        c,
        n_layers=2 * period,
        d_model=64,
        n_heads=n_heads,
        n_kv_heads=kv,
        d_head=16,
        d_ff=128,
        vocab=512,
        window=min(c.window, 32) if c.window else None,
        n_routed=min(c.n_routed, 8) if c.n_routed else 0,
        n_padded=min(c.n_padded, 8) if c.n_padded else 0,
        top_k=min(c.top_k, 2) if c.top_k else 0,
        n_shared=min(c.n_shared, 1) if c.n_shared else 0,
        d_expert=32 if c.d_expert else 0,
        n_frontend_tokens=8 if c.frontend else 0,
    )


def input_specs(cfg: ArchConfig, shape: str, max_cache: Optional[int] = None
                ) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of (arch, shape).

    train:   {tokens, targets [, frontend_embeds]}
    prefill: {tokens [, frontend_embeds]}
    decode:  {token, cache_len}  (caches are built by the launcher from
             transformer.init_caches eval_shape)
    """
    ss = SHAPES[shape]
    b, s = ss.global_batch, ss.seq_len
    i32 = jnp.int32
    if ss.kind == "train":
        out = {
            "tokens": jax.ShapeDtypeStruct((b, s), i32),
            "targets": jax.ShapeDtypeStruct((b, s), i32),
        }
        fe = stubs.frontend_spec(cfg.frontend, b, cfg.n_frontend_tokens,
                                 cfg.d_model)
        if fe is not None:
            out["frontend_embeds"] = fe
        return out
    if ss.kind == "prefill":
        out = {"tokens": jax.ShapeDtypeStruct((b, s), i32)}
        fe = stubs.frontend_spec(cfg.frontend, b, cfg.n_frontend_tokens,
                                 cfg.d_model)
        if fe is not None:
            out["frontend_embeds"] = fe
        return out
    if ss.kind == "decode":
        return {
            "token": jax.ShapeDtypeStruct((b,), i32),
            "cache_len": jax.ShapeDtypeStruct((b,), i32),
        }
    raise ValueError(ss.kind)
