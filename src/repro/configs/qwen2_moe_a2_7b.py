"""qwen2-moe-a2.7b [hf:Qwen/Qwen1.5-MoE-A2.7B]: 4 shared + 60 routed top-4.

60 does not divide the 16-wide model axis: experts are padded to 64 with
router-masked dummies (n_padded) so expert-parallelism stays legal --
the divisibility fallback documented in DESIGN.md SSArch-applicability."""
from repro.models.transformer import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-moe-a2.7b", family="moe",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=16, d_head=128,
    d_ff=1408, vocab=151936,
    moe_every=1, n_routed=60, top_k=4, n_shared=4, d_expert=1408,
    n_padded=64,
)
