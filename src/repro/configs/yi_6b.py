"""yi-6b [arXiv:2403.04652; hf]: llama-arch GQA with 4 KV heads."""
from repro.models.transformer import ArchConfig

CONFIG = ArchConfig(
    name="yi-6b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=4, d_head=128,
    d_ff=11008, vocab=64000,
)
