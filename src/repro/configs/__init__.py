from repro.configs.base import (ARCHS, SHAPES, get_arch, get_reduced,
                                input_specs, list_archs)

__all__ = ["ARCHS", "SHAPES", "get_arch", "get_reduced", "input_specs",
           "list_archs"]
