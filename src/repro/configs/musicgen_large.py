"""musicgen-large [arXiv:2306.05284; hf]: decoder-only LM over EnCodec
tokens; the EnCodec frontend is a stub supplying frame embeddings."""
from repro.models.transformer import ArchConfig

CONFIG = ArchConfig(
    name="musicgen-large", family="audio",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=32, d_head=64,
    d_ff=8192, vocab=2048,
    frontend="audio", n_frontend_tokens=250,
)
