"""mistral-large-123b [hf:mistralai/Mistral-Large-Instruct-2407]:
the deep/wide dense stress case (88 layers, d_model 12288)."""
from repro.models.transformer import ArchConfig

CONFIG = ArchConfig(
    name="mistral-large-123b", family="dense",
    n_layers=88, d_model=12288, n_heads=96, n_kv_heads=8, d_head=128,
    d_ff=28672, vocab=32768,
)
