"""rwkv6-1.6b "Finch" [arXiv:2404.05892]: attention-free, data-dependent
decay linear recurrence.  O(1) decode state -> runs long_500k."""
from repro.models.transformer import ArchConfig

CONFIG = ArchConfig(
    name="rwkv6-1.6b", family="ssm",
    n_layers=24, d_model=2048, n_heads=32, n_kv_heads=32, d_head=64,
    d_ff=7168, vocab=65536,
    rwkv=True,
    subquadratic=True,
)
