"""Deterministic, host-shardable, exactly-resumable synthetic LM data.

Every batch is a pure function of (seed, step, shard) -- counter-based RNG,
no iterator state -- so:
  * checkpoint/restore of the pipeline is just the step integer,
  * elastic re-sharding (hosts join/leave) re-partitions batches without
    replaying history,
  * any batch can be re-materialised for bitwise-identical replay/debug.

The stream is a noisy affine 2-gram process, t_{i+1} = (a*t_i + c + e) mod V
with e ~ small uniform noise: enough learnable structure that the example
trainer's loss drops well below ln(V), while staying fully synthetic.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    noise: int = 4                # e in [0, noise)
    frontend_tokens: int = 0      # synth embeddings for vlm/audio archs
    d_model: int = 0


class Pipeline:
    """Stateless batch source; `shard`/`n_shards` split the global batch."""

    def __init__(self, cfg: DataConfig, shard: int = 0, n_shards: int = 1):
        assert cfg.global_batch % n_shards == 0
        self.cfg = cfg
        self.shard = shard
        self.n_shards = n_shards
        self.local_batch = cfg.global_batch // n_shards
        # fixed per-seed affine params (coprime multiplier)
        rng = np.random.default_rng(cfg.seed)
        self.a = int(rng.integers(1, cfg.vocab - 1)) | 1
        self.c = int(rng.integers(0, cfg.vocab))

    def _rng(self, step: int) -> np.random.Generator:
        return np.random.default_rng(
            np.random.Philox(key=self.cfg.seed,
                             counter=[step, self.shard, 0, 0]))

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rng = self._rng(step)
        b, s, v = self.local_batch, cfg.seq_len, cfg.vocab
        toks = np.empty((b, s + 1), np.int32)
        toks[:, 0] = rng.integers(0, v, b)
        noise = rng.integers(0, max(cfg.noise, 1), (b, s))
        for i in range(s):
            toks[:, i + 1] = (toks[:, i] * self.a + self.c
                              + noise[:, i]) % v
        out = {"tokens": toks[:, :-1], "targets": toks[:, 1:]}
        if cfg.frontend_tokens:
            out["frontend_embeds"] = rng.normal(
                0, 0.02, (b, cfg.frontend_tokens, cfg.d_model)
            ).astype(np.float32)
        return out

    # ---------------------------------------------------- checkpointing

    def state(self, step: int) -> Dict[str, int]:
        return {"step": step, "seed": self.cfg.seed,
                "shard": self.shard, "n_shards": self.n_shards}

    @staticmethod
    def resume(cfg: DataConfig, state: Dict[str, int],
               shard: Optional[int] = None, n_shards: Optional[int] = None
               ) -> "Pipeline":
        """Resume, possibly onto a different shard split (elastic)."""
        return Pipeline(cfg,
                        shard if shard is not None else state["shard"],
                        n_shards if n_shards is not None else
                        state["n_shards"])
