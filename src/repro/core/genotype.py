"""Three-tier genotype (paper Fig. 2) and its fixed-shape JAX decoder.

A candidate placement is encoded as, per hard-block type t in {URAM,DSP,BRAM}:

  distribution  dist_t  f32[C_t]   how many cascade *chains* land in each
                                   (sub)column (softmax share of N_t chains,
                                   capacity-clipped exactly),
  location      loc_t   f32[N_t]   relative position of each chain within its
                                   column, in [0,1),
  mapping       perm_t  i32[N_t]   permutation: logical chain role -> physical
                                   chain (which placed chains form which conv
                                   unit).

Cascade constraints (Eq. 5) are *encoded*, not legalised after the fact: the
decoder only ever emits chains as contiguous cascade-legal site runs
(BRAM parity handled by sub-columns), so every genotype decodes to a legal
placement -- the paper's key search-space reduction (SS III-A.3).

The decoder is pure JAX with static shapes: a whole population decodes with
one `vmap`, and whole populations of populations (islands) with `shard_map`.

Two encodings are supported:
  * structured (dict of per-type arrays)   -- NSGA-II / GA operators,
  * flat continuous vector z in R^D        -- CMA-ES / SA; permutations via
    random keys (argsort), the classic continuous relaxation the paper's
    CMA-ES needs ("crossover and mutation become adding Gaussian noise").

`decode_reduced` implements the paper SS IV-B2 reduced genotype: mapping only,
blocks uniformly distributed and stacked bottom-up.
"""
from __future__ import annotations

import functools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.fpga.device import BRAM, DSP, URAM
from repro.fpga.netlist import Problem, TypeGeom

Genotype = Dict[str, Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]]
TYPES = (URAM, DSP, BRAM)


# ---------------------------------------------------------------- utilities

def _seg_cummax(vals: jnp.ndarray, segs: jnp.ndarray) -> jnp.ndarray:
    """Segment-wise running max (segments = contiguous equal ids)."""

    def comb(a, b):
        sa, va = a
        sb, vb = b
        return sb, jnp.where(sa == sb, jnp.maximum(va, vb), vb)

    _, out = lax.associative_scan(comb, (segs, vals))
    return out


def allocate_counts(genes: jnp.ndarray, caps: jnp.ndarray,
                    total: int) -> jnp.ndarray:
    """Exact capacity-respecting proportional allocation.

    softmax share -> floor -> leftover water-filled by fractional priority.
    Always sums to `total` when sum(caps) >= total, never exceeds caps.
    """
    p = jax.nn.softmax(genes.astype(jnp.float32))
    desired = p * total
    base = jnp.minimum(jnp.floor(desired), caps.astype(jnp.float32))
    base = base.astype(jnp.int32)
    rem = total - jnp.sum(base)
    room = caps.astype(jnp.int32) - base
    prio = desired - base.astype(jnp.float32)          # in [0,1); 0 if capped
    prio = jnp.where(room > 0, prio, -1.0)
    order = jnp.argsort(-prio)
    room_s = room[order]
    cum_before = jnp.cumsum(room_s) - room_s
    give_s = jnp.clip(rem - cum_before, 0, room_s)
    give = jnp.zeros_like(base).at[order].set(give_s.astype(jnp.int32))
    return base + give


def _decode_type(geom: TypeGeom, dist: jnp.ndarray, loc: jnp.ndarray
                 ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Decode one hard-block type to physical chain-member coordinates.

    Returns (x, y) each of shape [N_chains, chain_len] in RPM units.
    """
    N, L = geom.n_chains, geom.chain_len
    caps = jnp.asarray(geom.col_cap_chains)
    counts = allocate_counts(dist, caps, N)

    bounds = jnp.cumsum(counts)                       # exclusive upper bounds
    chain_idx = jnp.arange(N)
    col = jnp.searchsorted(bounds, chain_idx, side="right").astype(jnp.int32)
    col = jnp.clip(col, 0, geom.n_cols - 1)

    # within-column order by location gene: single global sort on (col, loc)
    locc = jnp.clip(loc, 0.0, 1.0 - 1e-6)
    key = col.astype(jnp.float32) * 2.0 + locc
    order = jnp.argsort(key)
    col_s = col[order]
    loc_s = locc[order]
    col_start = (bounds - counts)[col_s]
    rank_s = jnp.arange(N) - col_start                # rank within column

    # spread slack slots according to location genes, monotone within column
    slack_sites = ((caps - counts) * L)[col_s].astype(jnp.float32)
    off = jnp.floor(loc_s * (slack_sites + 1.0))
    off = jnp.minimum(off, slack_sites)
    off = _seg_cummax(off, col_s)                     # keep packing legal
    ystart_s = rank_s * L + off.astype(jnp.int32)

    ystart = jnp.zeros(N, jnp.int32).at[order].set(ystart_s)

    member = jnp.arange(L)[None, :]
    site = ystart[:, None] + member                   # sub-column site index
    parity = jnp.asarray(geom.col_parity)[col][:, None]
    phys_row = site * geom.site_step + parity
    y = phys_row.astype(jnp.float32) * geom.row_pitch
    x = jnp.asarray(geom.col_x)[col][:, None] * jnp.ones((1, L), jnp.float32)
    return x, y


# ------------------------------------------------------------------ decode

@functools.partial(jax.jit, static_argnums=0)
def decode(problem: Problem, g: Genotype) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Genotype -> logical-block coordinates (x[G], y[G]) in RPM units.

    Logical gid order is unit-major (see netlist._ROLE_LAYOUT); the mapping
    permutation routes logical chain roles onto physical chains.
    """
    xs, ys = [], []
    for t in TYPES:
        x, y = _decode_type(problem.geom[t], g["dist"][t], g["loc"][t])
        perm = g["perm"][t]
        xs.append(x[perm].reshape(-1))
        ys.append(y[perm].reshape(-1))
    xcat = jnp.concatenate(xs)
    ycat = jnp.concatenate(ys)
    pos = jnp.asarray(problem.blk_flatpos)
    return xcat[pos], ycat[pos]


def reduced_to_full(problem: Problem, perms: Tuple[jnp.ndarray, ...]
                    ) -> Genotype:
    """Lift a mapping-only genotype to the full composite encoding:
    distribution proportional to column capacity, location packed bottom-up.
    """
    return {
        "dist": tuple(jnp.log(jnp.asarray(
            problem.geom[t].col_cap_chains, jnp.float32) + 1e-3)
            for t in TYPES),
        "loc": tuple(jnp.zeros(problem.geom[t].n_chains) for t in TYPES),
        "perm": tuple(perms),
    }


@functools.partial(jax.jit, static_argnums=0)
def decode_reduced(problem: Problem, perms: Tuple[jnp.ndarray, ...]
                   ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Paper SS IV-B2: mapping-only genotype.

    ~1.8x less decode work, larger bounding boxes.
    """
    return decode(problem, reduced_to_full(problem, perms))


# ----------------------------------------------------- encodings / sampling

def random_genotype(key: jax.Array, problem: Problem) -> Genotype:
    ks = jax.random.split(key, 9)
    dist, loc, perm = [], [], []
    for i, t in enumerate(TYPES):
        geom = problem.geom[t]
        dist.append(jax.random.normal(ks[i], (geom.n_cols,)) * 0.5)
        loc.append(jax.random.uniform(ks[3 + i], (geom.n_chains,)))
        perm.append(jax.random.permutation(ks[6 + i], geom.n_chains)
                    .astype(jnp.int32))
    return {"dist": tuple(dist), "loc": tuple(loc), "perm": tuple(perm)}


def flat_dim(problem: Problem) -> int:
    return problem.continuous_dim


def flat_split(problem: Problem):
    """Static slices of the flat continuous vector."""
    sizes = []
    for part in ("dist", "loc", "map"):
        for t in TYPES:
            g = problem.geom[t]
            sizes.append(g.n_cols if part == "dist" else g.n_chains)
    offs = np.concatenate([[0], np.cumsum(sizes)])
    return [(int(offs[i]), int(offs[i + 1])) for i in range(len(sizes))]


@functools.partial(jax.jit, static_argnums=0)
def from_flat(problem: Problem, z: jnp.ndarray) -> Genotype:
    """Continuous vector -> structured genotype (perm via argsort keys)."""
    sl = flat_split(problem)
    dist = tuple(z[a:b] for (a, b) in sl[0:3])
    loc = tuple(jax.nn.sigmoid(z[a:b]) for (a, b) in sl[3:6])
    perm = tuple(jnp.argsort(z[a:b]).astype(jnp.int32) for (a, b) in sl[6:9])
    return {"dist": dist, "loc": loc, "perm": perm}


def to_flat(problem: Problem, g: Genotype) -> jnp.ndarray:
    """Structured -> flat continuous (inverse up to argsort equivalence).

    Used to seed CMA-ES / SA from a structured genotype (transfer learning).
    """
    parts = []
    for t in TYPES:
        parts.append(g["dist"][t])
    for t in TYPES:
        x = jnp.clip(g["loc"][t], 1e-4, 1 - 1e-4)
        parts.append(jnp.log(x) - jnp.log1p(-x))      # logit
    for t in TYPES:
        n = problem.geom[t].n_chains
        # keys whose argsort reproduces the permutation
        ranks = jnp.zeros(n).at[g["perm"][t]].set(jnp.arange(n, dtype=jnp.float32))
        parts.append(ranks / jnp.maximum(n - 1, 1) * 2.0 - 1.0)
    return jnp.concatenate(parts)
