"""sep-CMA-ES: the linear-time/space diagonal CMA-ES of Ros & Hansen (2008),
the "high-dimensional variant [26]" the paper uses for placement.

Operates on the flat continuous genotype encoding (distribution genes raw,
location genes via sigmoid, mapping permutations via random keys + argsort),
so "crossover and mutation become adding Gaussian noise to the samplings"
exactly as in paper SS II-D.  Fitness is the scalarized combined objective
log(wirelength^2) + log(max bbox).

State update uses the standard CMA-ES machinery restricted to a diagonal
covariance, with the separable learning-rate speedup c_cov *= (n+2)/3.
One generation = one jitted XLA program; sampling + evaluation are vmapped.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import genotype as G
from repro.core import objectives as O
from repro.fpga.netlist import Problem


@dataclasses.dataclass(frozen=True)
class CMAESConfig:
    pop_size: int = 0            # 0 -> 4 + floor(3 ln n)
    sigma0: float = 0.3
    fused: bool = False          # route evaluation through ops.fused_eval

    def lam(self, n: int) -> int:
        return self.pop_size if self.pop_size > 0 else 4 + int(3 * math.log(n))


def _constants(n: int, lam: int):
    mu = lam // 2
    w = jnp.log(mu + 0.5) - jnp.log(jnp.arange(1, mu + 1, dtype=jnp.float32))
    w = w / jnp.sum(w)
    mu_eff = 1.0 / jnp.sum(w ** 2)
    c_sigma = (mu_eff + 2.0) / (n + mu_eff + 5.0)
    d_sigma = (1.0 + 2.0 * jnp.maximum(
        0.0, jnp.sqrt((mu_eff - 1.0) / (n + 1.0)) - 1.0) + c_sigma)
    c_c = (4.0 + mu_eff / n) / (n + 4.0 + 2.0 * mu_eff / n)
    c_1 = 2.0 / ((n + 1.3) ** 2 + mu_eff)
    c_mu = jnp.minimum(
        1.0 - c_1,
        2.0 * (mu_eff - 2.0 + 1.0 / mu_eff) / ((n + 2.0) ** 2 + mu_eff))
    # separable speedup (Ros & Hansen 2008): diagonal model learns ~n/3 faster
    sep = (n + 2.0) / 3.0
    c_1 = jnp.minimum(1.0, c_1 * sep)
    c_mu = jnp.minimum(1.0 - c_1, c_mu * sep)
    chi_n = math.sqrt(n) * (1.0 - 1.0 / (4.0 * n) + 1.0 / (21.0 * n * n))
    return dict(mu=mu, w=w, mu_eff=mu_eff, c_sigma=c_sigma, d_sigma=d_sigma,
                c_c=c_c, c_1=c_1, c_mu=c_mu, chi_n=chi_n)


def init_state(problem: Problem, key: jax.Array, cfg: CMAESConfig,
               mean0: Optional[jnp.ndarray] = None) -> Dict:
    n = problem.continuous_dim
    mean = (jnp.asarray(mean0, jnp.float32) if mean0 is not None
            else jax.random.normal(key, (n,)) * 0.1)
    return {
        "mean": mean,
        "sigma": jnp.asarray(cfg.sigma0, jnp.float32),
        "c_diag": jnp.ones(n, jnp.float32),
        "p_sigma": jnp.zeros(n, jnp.float32),
        "p_c": jnp.zeros(n, jnp.float32),
        "gen": jnp.int32(0),
        "best_objs": jnp.array([jnp.inf, jnp.inf], jnp.float32),
        "best_z": mean,
    }


def step_impl(problem: Problem, cfg: CMAESConfig, state: Dict, key: jax.Array
              ) -> Dict:
    """Unjitted body: float config fields may be traced (portfolio)."""
    n = problem.continuous_dim
    lam = cfg.lam(n)
    c = _constants(n, lam)
    mu, w = c["mu"], c["w"]

    z = jax.random.normal(key, (lam, n))
    y = z * jnp.sqrt(state["c_diag"])[None, :]
    x = state["mean"][None, :] + state["sigma"] * y

    objs = O.evaluate_flat_population(problem, x, cfg.fused)   # [lam, 2]
    fit = O.scalarize(objs)
    order = jnp.argsort(fit)
    y_sel = y[order[:mu]]                                  # [mu, n]
    z_sel = z[order[:mu]]

    y_w = jnp.sum(w[:, None] * y_sel, axis=0)
    z_w = jnp.sum(w[:, None] * z_sel, axis=0)
    mean = state["mean"] + state["sigma"] * y_w

    p_sigma = ((1.0 - c["c_sigma"]) * state["p_sigma"]
               + jnp.sqrt(c["c_sigma"] * (2.0 - c["c_sigma"]) * c["mu_eff"])
               * z_w)
    ps_norm = jnp.linalg.norm(p_sigma)
    sigma = state["sigma"] * jnp.exp(
        (c["c_sigma"] / c["d_sigma"]) * (ps_norm / c["chi_n"] - 1.0))

    gen = state["gen"] + 1
    h_sig = (ps_norm / jnp.sqrt(
        1.0 - (1.0 - c["c_sigma"]) ** (2.0 * gen)) / c["chi_n"]
        < 1.4 + 2.0 / (n + 1.0)).astype(jnp.float32)
    p_c = ((1.0 - c["c_c"]) * state["p_c"]
           + h_sig * jnp.sqrt(c["c_c"] * (2.0 - c["c_c"]) * c["mu_eff"])
           * y_w)

    rank_mu = jnp.sum(w[:, None] * (y_sel ** 2), axis=0)
    c_diag = ((1.0 - c["c_1"] - c["c_mu"]) * state["c_diag"]
              + c["c_1"] * (p_c ** 2
                            + (1.0 - h_sig) * c["c_c"]
                            * (2.0 - c["c_c"]) * state["c_diag"])
              + c["c_mu"] * rank_mu)
    c_diag = jnp.maximum(c_diag, 1e-12)

    best_i = order[0]
    improved = fit[best_i] < O.scalarize(state["best_objs"])
    best_objs = jnp.where(improved, objs[best_i], state["best_objs"])
    best_z = jnp.where(improved, x[best_i], state["best_z"])

    return {"mean": mean, "sigma": sigma, "c_diag": c_diag,
            "p_sigma": p_sigma, "p_c": p_c, "gen": gen,
            "best_objs": best_objs, "best_z": best_z}


step = functools.partial(jax.jit, static_argnums=(0, 1))(step_impl)


def best_genotype(problem: Problem, state: Dict) -> Tuple[G.Genotype,
                                                          jnp.ndarray]:
    return G.from_flat(problem, state["best_z"]), state["best_objs"]
