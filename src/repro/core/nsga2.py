"""NSGA-II for hard-block placement -- fully vectorized, fixed-shape JAX.

Implements Deb et al.'s elitist multi-objective GA with:
  * fast non-dominated sorting from the P x P domination matrix
    (Pallas kernel on TPU, `kernels.domination`),
  * crowding distance with exact per-front normalisation,
  * crowded binary tournament selection,
  * SBX crossover + polynomial mutation on the real genotype tiers
    (distribution, location),
  * fixed-shape order crossover (OX) + swap mutation on the mapping
    permutations -- the paper's composite-genotype operators (SS III-A.1),
  * the SS IV-B2 *reduced genotype* variant (mapping only).

All operators are jit/vmap-safe; one generation is a single XLA program.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core import genotype as G
from repro.core import objectives as O
from repro.fpga.netlist import Problem
from repro.kernels import ops

INF = jnp.float32(1e9)


@dataclasses.dataclass(frozen=True)
class NSGA2Config:
    pop_size: int = 64
    crossover_prob: float = 0.9
    sbx_eta: float = 15.0
    mut_eta: float = 20.0
    real_mut_prob: float = 0.1     # per-gene polynomial mutation prob
    perm_swaps: int = 2            # swap mutations per child permutation
    perm_swap_prob: float = 0.6
    reduced: bool = False          # SS IV-B2 mapping-only genotype
    fused: bool = False            # route evaluation through ops.fused_eval


# ------------------------------------------------- non-dominated sorting

def nondominated_rank(objs: jnp.ndarray, fused: bool = False) -> jnp.ndarray:
    """[P, M] objectives -> [P] int32 Pareto front index (0 = best).

    `fused=True` takes the matrix and its column counts from one kernel
    launch (`ops.fused_domination_counts`); the default branch is the
    original two-step computation, untouched.
    """
    p = objs.shape[0]
    if fused:
        dom_b, ndom = ops.fused_domination_counts(objs)
        dom = dom_b.astype(jnp.int32)                        # dom[i,j]: i>j
    else:
        dom = ops.domination_matrix(objs).astype(jnp.int32)  # dom[i,j]: i>j
        ndom = jnp.sum(dom, axis=0)                          # dominated-by ct

    def body(r, carry):
        rank, nd = carry
        front = (nd == 0) & (rank == p)
        rank = jnp.where(front, r, rank)
        release = jnp.sum(dom * front[:, None].astype(jnp.int32), axis=0)
        nd = jnp.where(front, -1, nd - release)
        return rank, nd

    rank, _ = jax.lax.fori_loop(0, p, body, (jnp.full(p, p, jnp.int32), ndom))
    return rank


def crowding_distance(objs: jnp.ndarray, rank: jnp.ndarray) -> jnp.ndarray:
    """Crowding distance within each front (boundaries get INF)."""
    p, m = objs.shape
    crowd = jnp.zeros(p, jnp.float32)
    # exact per-front objective ranges via scatter-min/max into rank buckets
    for mm in range(m):
        f = objs[:, mm].astype(jnp.float32)
        fmax = jnp.full(p, -jnp.inf).at[rank].max(f)[rank]
        fmin = jnp.full(p, jnp.inf).at[rank].min(f)[rank]
        rng = jnp.maximum(fmax - fmin, 1e-12)
        # exact lexicographic (rank, f) sort: two stable argsorts
        o1 = jnp.argsort(f, stable=True)
        order = o1[jnp.argsort(rank[o1], stable=True)]
        fs = f[order]
        rs = rank[order]
        prev = jnp.concatenate([fs[:1], fs[:-1]])
        nxt = jnp.concatenate([fs[1:], fs[-1:]])
        same_prev = jnp.concatenate(
            [jnp.array([False]), rs[1:] == rs[:-1]])
        same_next = jnp.concatenate(
            [rs[:-1] == rs[1:], jnp.array([False])])
        d = jnp.where(same_prev & same_next,
                      (nxt - prev) / rng[order], INF)
        crowd = crowd + jnp.zeros(p).at[order].set(d)
    return crowd


# ------------------------------------------------------------- operators

def _sbx(key, a: jnp.ndarray, b: jnp.ndarray, eta: float,
         prob: float) -> jnp.ndarray:
    k1, k2, k3 = jax.random.split(key, 3)
    u = jax.random.uniform(k1, a.shape)
    beta = jnp.where(u <= 0.5,
                     (2.0 * u) ** (1.0 / (eta + 1.0)),
                     (1.0 / (2.0 * (1.0 - u) + 1e-12)) ** (1.0 / (eta + 1.0)))
    sign = jnp.where(jax.random.bernoulli(k2, 0.5, a.shape), 1.0, -1.0)
    child = 0.5 * ((a + b) + sign * beta * (a - b))
    do = jax.random.bernoulli(k3, prob, a.shape)
    return jnp.where(do, child, a)


def _poly_mut(key, x: jnp.ndarray, eta: float, prob: float,
              scale: float = 1.0) -> jnp.ndarray:
    k1, k2 = jax.random.split(key)
    u = jax.random.uniform(k1, x.shape)
    d = jnp.where(u < 0.5,
                  (2.0 * u) ** (1.0 / (eta + 1.0)) - 1.0,
                  1.0 - (2.0 * (1.0 - u)) ** (1.0 / (eta + 1.0)))
    do = jax.random.bernoulli(k2, prob, x.shape)
    return x + jnp.where(do, d * scale, 0.0)


def _ox(key, p1: jnp.ndarray, p2: jnp.ndarray) -> jnp.ndarray:
    """Fixed-shape order crossover: child keeps p1's segment [a, b), fills
    the remaining slots left-to-right with p2's values in p2 order."""
    n = p1.shape[0]
    k1, k2 = jax.random.split(key)
    cuts = jnp.sort(jax.random.randint(k1, (2,), 0, n + 1))
    a, b = cuts[0], cuts[1]
    pos = jnp.arange(n)
    seg = (pos >= a) & (pos < b)
    taken = jnp.zeros(n + 1, bool).at[jnp.where(seg, p1, n)].set(True)[:n]
    # order positions: non-segment slots first (stable), then segment slots
    pos_order = jnp.argsort(seg, stable=True)
    # order values: untaken values in p2 order first, then the taken ones
    val_order = jnp.argsort(taken[p2], stable=True)
    n_free = n - (b - a)
    fill = jnp.where(jnp.arange(n) < n_free, p2[val_order], p1[pos_order])
    return jnp.zeros(n, p1.dtype).at[pos_order].set(fill)


def _swap_mut(key, perm: jnp.ndarray, n_swaps: int, prob: float
              ) -> jnp.ndarray:
    n = perm.shape[0]

    def one(carry, k):
        p = carry
        ki, kj, kd = jax.random.split(k, 3)
        i = jax.random.randint(ki, (), 0, n)
        j = jax.random.randint(kj, (), 0, n)
        do = jax.random.bernoulli(kd, prob)
        pi, pj = p[i], p[j]
        p = p.at[i].set(jnp.where(do, pj, pi)).at[j].set(
            jnp.where(do, pi, pj))
        return p, None

    perm, _ = jax.lax.scan(one, perm, jax.random.split(key, n_swaps))
    return perm


def _vary_one(key, g1: G.Genotype, g2: G.Genotype,
              cfg: NSGA2Config) -> G.Genotype:
    """Produce one child from two parents (full composite genotype)."""
    keys = jax.random.split(key, 12)
    dist, loc, perm = [], [], []
    for t in range(3):
        d = _sbx(keys[t], g1["dist"][t], g2["dist"][t],
                 cfg.sbx_eta, cfg.crossover_prob)
        d = _poly_mut(keys[3 + t], d, cfg.mut_eta, cfg.real_mut_prob, 1.0)
        dist.append(d)
        l = _sbx(keys[6 + t], g1["loc"][t], g2["loc"][t],
                 cfg.sbx_eta, cfg.crossover_prob)
        l = _poly_mut(keys[9 + t], l, cfg.mut_eta, cfg.real_mut_prob, 0.25)
        loc.append(jnp.clip(l, 0.0, 1.0))
    pkeys = jax.random.split(keys[11], 6)
    for t in range(3):
        c = _ox(pkeys[t], g1["perm"][t], g2["perm"][t])
        c = _swap_mut(pkeys[3 + t], c, cfg.perm_swaps, cfg.perm_swap_prob)
        perm.append(c)
    return {"dist": tuple(dist), "loc": tuple(loc), "perm": tuple(perm)}


def _vary_one_reduced(key, g1, g2, cfg: NSGA2Config):
    pkeys = jax.random.split(key, 6)
    perm = []
    for t in range(3):
        c = _ox(pkeys[t], g1[t], g2[t])
        c = _swap_mut(pkeys[3 + t], c, cfg.perm_swaps, cfg.perm_swap_prob)
        perm.append(c)
    return tuple(perm)


# ------------------------------------------------------------- algorithm

def _tournament(key, rank, crowd, n: int) -> jnp.ndarray:
    p = rank.shape[0]
    ka, kb = jax.random.split(key)
    ia = jax.random.randint(ka, (n,), 0, p)
    ib = jax.random.randint(kb, (n,), 0, p)
    better = (rank[ia] < rank[ib]) | (
        (rank[ia] == rank[ib]) & (crowd[ia] > crowd[ib]))
    return jnp.where(better, ia, ib)


def _lexsort_rank_crowd(rank, crowd):
    order1 = jnp.argsort(-crowd, stable=True)
    order2 = jnp.argsort(rank[order1], stable=True)
    return order1[order2]


def init_state(problem: Problem, key: jax.Array, cfg: NSGA2Config
               ) -> Dict[str, jnp.ndarray]:
    keys = jax.random.split(key, cfg.pop_size)
    if cfg.reduced:
        pop = jax.vmap(
            lambda k: tuple(G.random_genotype(k, problem)["perm"]))(keys)
        objs = _eval_reduced(problem, pop, cfg.fused)
    else:
        pop = jax.vmap(lambda k: G.random_genotype(k, problem))(keys)
        objs = O.evaluate_population(problem, pop, cfg.fused)
    return {"pop": pop, "objs": objs}


@functools.partial(jax.jit, static_argnums=(0, 2))
def _eval_reduced(problem: Problem, perms, fused: bool = False
                  ) -> jnp.ndarray:
    if fused:
        bx, by = jax.vmap(
            lambda ps: G.decode_reduced(problem, ps))(perms)
        s, d = jnp.asarray(problem.net_src), jnp.asarray(problem.net_dst)
        w = jnp.asarray(problem.net_w)
        return ops.fused_eval(bx, by, s, d, w, O.unit_index(problem))

    def one(ps):
        bx, by = G.decode_reduced(problem, ps)
        wl2, bb = O.objectives_from_coords(problem, bx, by)
        return jnp.stack([wl2, bb])

    return jax.vmap(one)(perms)


def step_impl(problem: Problem, cfg: NSGA2Config, state, key):
    """One NSGA-II generation: P children, (mu+lambda) truncation.

    Unjitted body: float config fields may be JAX tracers (portfolio
    batching); only `pop_size`, `perm_swaps`, `reduced` must be concrete.
    """
    pop, objs = state["pop"], state["objs"]
    p = cfg.pop_size
    rank = nondominated_rank(objs, cfg.fused)
    crowd = crowding_distance(objs, rank)
    k1, k2, k3 = jax.random.split(key, 3)
    pa = _tournament(k1, rank, crowd, p)
    pb = _tournament(k2, rank, crowd, p)

    def take(idx):
        return jax.tree.map(lambda a: a[idx], pop)

    vary = _vary_one_reduced if cfg.reduced else _vary_one
    children = jax.vmap(lambda k, g1, g2: vary(k, g1, g2, cfg))(
        jax.random.split(k3, p), take(pa), take(pb))
    cobjs = (_eval_reduced(problem, children, cfg.fused) if cfg.reduced
             else O.evaluate_population(problem, children, cfg.fused))

    # (mu + lambda) environmental selection on the combined population
    allpop = jax.tree.map(lambda a, b: jnp.concatenate([a, b]), pop, children)
    allobjs = jnp.concatenate([objs, cobjs])
    arank = nondominated_rank(allobjs, cfg.fused)
    acrowd = crowding_distance(allobjs, arank)
    order = _lexsort_rank_crowd(arank, acrowd)[:p]
    return {"pop": jax.tree.map(lambda a: a[order], allpop),
            "objs": allobjs[order]}


step = functools.partial(jax.jit, static_argnums=(0, 1))(step_impl)


def best(state) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(best combined-metric objectives, index)."""
    c = O.combined_metric(state["objs"])
    i = jnp.argmin(c)
    return state["objs"][i], i
