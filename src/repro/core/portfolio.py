"""Hyperparameter-portfolio runner: many placement runs, ONE compiled program.

RapidLayout's edge is wall-clock (paper Table I), and on accelerators
wall-clock comes from batch: GPU-batched placers evaluate thousands of
candidates per launch.  This module lifts that one level up -- instead of
batching candidates *within* one evolutionary run, it batches K whole
(config, seed) runs of `evolve.run` into a single jitted program via `vmap`
over the traced hyperparameters (`core.hyper`).  A portfolio of NSGA-II
configs with different `sbx_eta` / mutation rates races in the time of one.

Two entry points:

  * `run_portfolio`  -- fixed budget: all K members run `n_gens` generations
    in one program; per-member results match K independent `evolve.run`
    calls with the same keys (both paths route through `hyper.tracify`, so
    all hyperparameter arithmetic is f32 -- exact equality observed on CPU,
    verified to 1e-5 relative in tests/bench to stay robust to backends
    whose vmapped reductions round differently in the last bits).
  * `race`           -- early champion selection: members advance in rounds
    of `gens_per_round` generations (one compiled program per round shape,
    reused across rounds); between rounds the host checks the champion's
    `combined_metric` and stops once it stalls for `patience` rounds.

Static config fields (pop_size, perm_swaps, reduced, schedule) must agree
across members -- they fix shapes and branches of the compiled program.
Members that disagree belong in separate portfolios (or service pools).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import evolve, hyper
from repro.core import genotype as G
from repro.core import objectives as O
from repro.fpga.netlist import Problem


# --------------------------------------------------------- member programs

def member_init(problem: Problem, algo: str, static_key: hyper.StaticKey,
                traced: Dict[str, jnp.ndarray], key: jax.Array) -> Dict:
    """Init one member's algorithm state (float hyperparams may be traced)."""
    cfg = hyper.tracify(hyper.merge_config(static_key, traced))
    return evolve.get_algo(algo).init_state(problem, key, cfg)


def member_round(problem: Problem, algo: str, static_key: hyper.StaticKey,
                 n_gens: int, traced: Dict[str, jnp.ndarray], state: Dict,
                 key: jax.Array) -> Tuple[Dict, jnp.ndarray]:
    """Advance one member `n_gens` generations; returns (state, best objs)."""
    cfg = hyper.tracify(hyper.merge_config(static_key, traced))
    m = evolve.get_algo(algo)

    def body(st, k):
        return m.step_impl(problem, cfg, st, k), None

    state, _ = jax.lax.scan(body, state, jax.random.split(key, n_gens))
    return state, evolve.state_best_objs(state)


@functools.partial(jax.jit, static_argnums=(0, 1, 2, 5))
def _vrun(problem, algo, static_key, traced, keys, n_gens):
    """K full runs in one program: vmap of `evolve._run_impl` over members."""
    return jax.vmap(
        lambda tr, k: evolve._run_impl(problem, algo,
                                       hyper.merge_config(static_key, tr),
                                       k, n_gens))(traced, keys)


@functools.partial(jax.jit, static_argnums=(0, 1, 2))
def _vinit(problem, algo, static_key, traced, keys):
    return jax.vmap(
        lambda tr, k: member_init(problem, algo, static_key, tr, k)
    )(traced, keys)


@functools.partial(jax.jit, static_argnums=(0, 1, 2, 6))
def _vround(problem, algo, static_key, traced, states, keys, n_gens):
    return jax.vmap(
        lambda tr, st, k: member_round(problem, algo, static_key, n_gens,
                                       tr, st, k))(traced, states, keys)


# ------------------------------------------------------------- fixed budget

@dataclasses.dataclass
class PortfolioResult:
    states: Dict                 # stacked member states (leading K axis)
    history: np.ndarray          # [K, n_gens, 2] per-gen best objectives
    best_objs: np.ndarray        # [K, 2] final best per member
    metric: np.ndarray           # [K] combined metric per member
    champion: int                # argmin(metric)

    def member_state(self, i: int) -> Dict:
        return jax.tree.map(lambda a: a[i], self.states)

    @property
    def champion_objs(self) -> np.ndarray:
        return self.best_objs[self.champion]


def run_portfolio(problem: Problem, algo: str, cfgs: Sequence,
                  key: Optional[jax.Array] = None, n_gens: int = 50,
                  keys: Optional[jax.Array] = None) -> PortfolioResult:
    """Run K = len(cfgs) (config, seed) members in one jitted program.

    `keys` gives each member its PRNG key explicitly ([K]-stacked); with
    only `key`, members get `jax.random.split(key, K)`.  Per-member results
    match `evolve.run(problem, algo, cfgs[i], keys[i], n_gens)`.
    """
    static_key, traced = hyper.stack_configs(cfgs)
    if keys is None:
        if key is None:
            raise ValueError("pass key= or keys=")
        keys = jax.random.split(key, len(cfgs))
    states, hist = _vrun(problem, algo, static_key, traced, keys, n_gens)
    best = np.asarray(jax.vmap(evolve.state_best_objs)(states))
    metric = np.asarray(O.combined_metric(jnp.asarray(best)))
    return PortfolioResult(states=states, history=np.asarray(hist),
                           best_objs=best, metric=metric,
                           champion=int(np.argmin(metric)))


# ------------------------------------------------------------------ racing

@dataclasses.dataclass
class RaceResult:
    states: Dict                 # stacked member states at stop time
    history: np.ndarray          # [rounds, K, 2] best objs after each round
    best_objs: np.ndarray        # [K, 2]
    metric: np.ndarray           # [K]
    champion: int
    rounds: int                  # rounds actually run (<= max budget)
    gens: int                    # generations per member actually run

    def member_state(self, i: int) -> Dict:
        return jax.tree.map(lambda a: a[i], self.states)

    @property
    def champion_objs(self) -> np.ndarray:
        return self.best_objs[self.champion]


def race(problem: Problem, algo: str, cfgs: Sequence, key: jax.Array,
         max_gens: int = 200, gens_per_round: int = 10,
         patience: int = 2, rtol: float = 1e-3) -> RaceResult:
    """Portfolio racing with early champion selection.

    All members advance together in rounds (one compiled round program,
    reused -- no recompiles); after each round the champion's combined
    metric is checked on the host, and the race stops early once it fails
    to improve by a relative `rtol` for `patience` consecutive rounds.
    """
    if max_gens < 1:
        raise ValueError(f"max_gens must be >= 1, got {max_gens}")
    static_key, traced = hyper.stack_configs(cfgs)
    k_init, k_run = jax.random.split(key)
    states = _vinit(problem, algo, static_key, traced,
                    jax.random.split(k_init, len(cfgs)))
    # budgets quantize UP to whole rounds, same convention as
    # PlacementService.submit(): ask for 15 gens in rounds of 10, get 20
    gens_per_round = min(gens_per_round, max_gens)
    n_rounds = -(-max_gens // gens_per_round)
    best_metric, stall = np.inf, 0
    hist: List[np.ndarray] = []
    rounds = 0
    best = None
    for r in range(n_rounds):
        keys = jax.random.split(jax.random.fold_in(k_run, r), len(cfgs))
        states, best = _vround(problem, algo, static_key, traced, states,
                               keys, gens_per_round)
        rounds = r + 1
        best = np.asarray(best)
        hist.append(best)
        m = float(np.min(O.combined_metric(best)))
        if m < best_metric * (1.0 - rtol):
            best_metric, stall = m, 0
        else:
            stall += 1
            if stall >= patience:
                break
    metric = np.asarray(O.combined_metric(best))
    return RaceResult(states=states, history=np.stack(hist),
                      best_objs=best, metric=metric,
                      champion=int(np.argmin(metric)), rounds=rounds,
                      gens=rounds * gens_per_round)


# --------------------------------------------------------------- champions

def best_genotype(problem: Problem, algo: str, state: Dict,
                  cfg=None) -> Tuple[G.Genotype, jnp.ndarray]:
    """Extract the best full genotype + objectives from one member's state.

    Handles population states (`pop`/`objs`), flat-encoding states
    (`best_z`, CMA-ES / SA), and the NSGA-II reduced (mapping-only) pop,
    which is lifted back to the full composite encoding.
    """
    if "best_z" in state:
        return (G.from_flat(problem, jnp.asarray(state["best_z"])),
                jnp.asarray(state["best_objs"]))
    objs = jnp.asarray(state["objs"])
    i = jnp.argmin(O.combined_metric(objs))
    g = jax.tree.map(lambda a: jnp.asarray(a)[i], state["pop"])
    if cfg is not None and getattr(cfg, "reduced", False):
        g = G.reduced_to_full(problem, g)
    return g, objs[i]
