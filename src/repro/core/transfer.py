"""Transfer learning across UltraScale+ devices (paper SS IV-D, Table II).

A converged genotype on a *seed* device warm-starts the search on a sibling
device: the three genotype tiers migrate independently --

  distribution : per-column genes map by relative x position (nearest
                 fractional-width neighbour between the two column sets),
  location     : per-chain genes tile periodically when the design grows,
  mapping      : the permutation extends order-preservingly (argsort of
                 tiled rank keys), keeping the seed's relative structure.

This is exactly what the three-tier genotype buys (paper SS III-A.3): each
tier is meaningful on its own, so it survives re-targeting to a device with
different column counts / arrangements.  The migrated genotype then seeds
CMA-ES (mean := seed, small sigma) or NSGA-II (population := seed + jitter).
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import genotype as G
from repro.fpga.netlist import Problem


def _norm01(x: np.ndarray) -> np.ndarray:
    """Column x coordinates -> relative positions in [0, 1].

    Single-column geometries (and coincident columns, e.g. BRAM parity
    sub-column pairs sharing one physical x) have zero spread; they take
    the degenerate path explicitly -- every column sits at relative 0 --
    instead of leaning on an epsilon denominator.
    """
    x = np.asarray(x, np.float64)
    if x.size == 0:
        raise ValueError("empty column set")
    span = float(np.ptp(x))
    if x.size == 1 or span <= 0.0:
        return np.zeros_like(x)
    return (x - x.min()) / span


def _map_columns(src_x: np.ndarray, dst_x: np.ndarray) -> np.ndarray:
    """For each dst column, the src column at the nearest relative x.

    Distance ties (duplicate x values: BRAM parity sub-columns share one
    physical column) break by relative *ordinal*, so identical column sets
    map to the identity -- same-geometry transfer is exact.
    """
    sx = _norm01(src_x)
    dx = _norm01(dst_x)
    d = np.abs(dx[:, None] - sx[None, :])
    so = np.arange(sx.size) / max(sx.size - 1, 1)
    do = np.arange(dx.size) / max(dx.size - 1, 1)
    d += np.abs(do[:, None] - so[None, :]) * 1e-6
    return np.argmin(d, axis=1)


def migrate(src: Problem, dst: Problem, g: G.Genotype) -> G.Genotype:
    """Project a genotype from the seed device's problem onto the target's."""
    dist, loc, perm = [], [], []
    for t in G.TYPES:
        gs, gd = src.geom[t], dst.geom[t]
        # distribution: nearest-relative-x column gene
        cmap = _map_columns(np.asarray(gs.col_x), np.asarray(gd.col_x))
        dist.append(jnp.asarray(np.asarray(g["dist"][t])[cmap]))
        # location: periodic tiling over the (possibly larger) chain count
        ls = np.asarray(g["loc"][t])
        idx = np.arange(gd.n_chains) % gs.n_chains
        loc.append(jnp.asarray(ls[idx]))
        # mapping: order-preserving extension.  Tile the seed permutation
        # block-wise into rank keys; argsort yields a valid permutation
        # that preserves the seed's relative order in every block.
        ps = np.asarray(g["perm"][t])
        n_rep = -(-gd.n_chains // gs.n_chains)
        keys = np.concatenate(
            [ps + r * gs.n_chains for r in range(n_rep)])[:gd.n_chains]
        # rank(keys) == keys when the sizes tile exactly (identity transfer
        # for same-geometry devices); otherwise ranks compact the overflow
        perm.append(jnp.asarray(np.argsort(np.argsort(keys)), jnp.int32))
    return {"dist": tuple(dist), "loc": tuple(loc), "perm": tuple(perm)}


def auto_migrate(src: Problem, dst: Problem, g: G.Genotype) -> G.Genotype:
    """Signature-routed transfer: the projection the *problems* call for.

    Same content signature -> the genotype is already a placement of the
    target (identity, no projection work); anything else -> `migrate`.
    This is the entry the champion store uses, so "same problem vs sibling
    problem" is decided by content hashes, never by the caller comparing
    device names.
    """
    if src.signature == dst.signature:
        return g
    return migrate(src, dst, g)


def converge_champion(problem: Problem, key: jax.Array, pop_size: int,
                      n_gens: int) -> G.Genotype:
    """Converge a base-device NSGA-II champion to seed transfers from.

    One `evolve.run` + best-by-combined-metric extraction -- the shared
    first step of every warm-start flow (bench, CLI demo, fleet example).
    """
    from repro.core import evolve
    from repro.core import nsga2 as N
    from repro.core import portfolio as P
    cfg = N.NSGA2Config(pop_size=pop_size)
    state, _ = evolve.run(problem, "nsga2", cfg, key, n_gens)
    g, _objs = P.best_genotype(problem, "nsga2", state, cfg)
    return g


def seed_population(problem: Problem, g_seed: G.Genotype, key: jax.Array,
                    pop_size: int, jitter: float = 0.15) -> Dict:
    """NSGA-II warm-start: seed + mutated copies (row 0 stays exact)."""
    from repro.core import warmstart as W
    from repro.core.nsga2 import NSGA2Config
    pop, fresh = W.canonicalize(problem, g_seed, pop_size)
    return W.warm_state(problem, "nsga2", NSGA2Config(pop_size=pop_size),
                        jax.tree.map(jnp.asarray, pop), jnp.asarray(fresh),
                        key, jnp.float32(jitter), jnp.float32(1.0))


def seed_cmaes(problem: Problem, g_seed: G.Genotype, key: jax.Array,
               sigma0: float = 0.08):
    """CMA-ES warm-start state centred on the migrated genotype."""
    from repro.core import cmaes as C
    from repro.core import warmstart as W
    cfg = C.CMAESConfig(sigma0=sigma0)
    pop, fresh = W.canonicalize(problem, g_seed, 1)
    state = W.warm_state(problem, "cmaes", cfg,
                         jax.tree.map(jnp.asarray, pop),
                         jnp.asarray(fresh), key,
                         jnp.float32(0.0), jnp.float32(1.0))
    return state, cfg
