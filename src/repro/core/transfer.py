"""Transfer learning across UltraScale+ devices (paper SS IV-D, Table II).

A converged genotype on a *seed* device warm-starts the search on a sibling
device: the three genotype tiers migrate independently --

  distribution : per-column genes map by relative x position (nearest
                 fractional-width neighbour between the two column sets),
  location     : per-chain genes tile periodically when the design grows,
  mapping      : the permutation extends order-preservingly (argsort of
                 tiled rank keys), keeping the seed's relative structure.

This is exactly what the three-tier genotype buys (paper SS III-A.3): each
tier is meaningful on its own, so it survives re-targeting to a device with
different column counts / arrangements.  The migrated genotype then seeds
CMA-ES (mean := seed, small sigma) or NSGA-II (population := seed + jitter).
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import genotype as G
from repro.fpga.netlist import Problem


def _map_columns(src_x: np.ndarray, dst_x: np.ndarray) -> np.ndarray:
    """For each dst column, the src column at the nearest relative x."""
    sx = (src_x - src_x.min()) / max(np.ptp(src_x), 1e-9)
    dx = (dst_x - dst_x.min()) / max(np.ptp(dst_x), 1e-9)
    return np.argmin(np.abs(dx[:, None] - sx[None, :]), axis=1)


def migrate(src: Problem, dst: Problem, g: G.Genotype) -> G.Genotype:
    """Project a genotype from the seed device's problem onto the target's."""
    dist, loc, perm = [], [], []
    for t in G.TYPES:
        gs, gd = src.geom[t], dst.geom[t]
        # distribution: nearest-relative-x column gene
        cmap = _map_columns(np.asarray(gs.col_x), np.asarray(gd.col_x))
        dist.append(jnp.asarray(np.asarray(g["dist"][t])[cmap]))
        # location: periodic tiling over the (possibly larger) chain count
        ls = np.asarray(g["loc"][t])
        idx = np.arange(gd.n_chains) % gs.n_chains
        loc.append(jnp.asarray(ls[idx]))
        # mapping: order-preserving extension.  Tile the seed permutation
        # block-wise into rank keys; argsort yields a valid permutation
        # that preserves the seed's relative order in every block.
        ps = np.asarray(g["perm"][t])
        n_rep = -(-gd.n_chains // gs.n_chains)
        keys = np.concatenate(
            [ps + r * gs.n_chains for r in range(n_rep)])[:gd.n_chains]
        # rank(keys) == keys when the sizes tile exactly (identity transfer
        # for same-geometry devices); otherwise ranks compact the overflow
        perm.append(jnp.asarray(np.argsort(np.argsort(keys)), jnp.int32))
    return {"dist": tuple(dist), "loc": tuple(loc), "perm": tuple(perm)}


def seed_population(problem: Problem, g_seed: G.Genotype, key: jax.Array,
                    pop_size: int, jitter: float = 0.15) -> Dict:
    """NSGA-II warm-start: seed + mutated copies (evaluated lazily by init)."""
    from repro.core import nsga2 as N
    from repro.core import objectives as O

    def jit_one(k):
        kk = jax.random.split(k, 7)
        dist = tuple(g_seed["dist"][t]
                     + jax.random.normal(kk[t], g_seed["dist"][t].shape)
                     * jitter for t in G.TYPES)
        loc = tuple(jnp.clip(
            g_seed["loc"][t]
            + jax.random.normal(kk[3 + t], g_seed["loc"][t].shape) * jitter,
            0.0, 1.0) for t in G.TYPES)
        perm = tuple(N._swap_mut(jax.random.fold_in(kk[6], t),
                                 g_seed["perm"][t], 2, 0.5) for t in G.TYPES)
        return {"dist": dist, "loc": loc, "perm": perm}

    pop = jax.vmap(jit_one)(jax.random.split(key, pop_size))
    # slot the unperturbed seed in at index 0
    pop = jax.tree.map(lambda a, s: a.at[0].set(s), pop, g_seed)
    objs = O.evaluate_population(problem, pop)
    return {"pop": pop, "objs": objs}


def seed_cmaes(problem: Problem, g_seed: G.Genotype, key: jax.Array,
               sigma0: float = 0.08):
    """CMA-ES warm-start state centred on the migrated genotype."""
    from repro.core import cmaes as C
    mean0 = G.to_flat(problem, g_seed)
    cfg = C.CMAESConfig(sigma0=sigma0)
    return C.init_state(problem, key, cfg, mean0=mean0), cfg
