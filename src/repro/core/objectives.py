"""Objective evaluation for placement genotypes (paper Eqs. 1-2).

`evaluate` maps one genotype to the two objectives; `evaluate_population`
vmaps the whole population through decode + objectives in a single jitted
program (the paper's per-candidate Java evaluation becomes one fused batch).
Hot reductions route through `repro.kernels.ops` (Pallas on TPU).
"""
from __future__ import annotations

import functools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import genotype as G
from repro.fpga.netlist import BLOCKS_PER_UNIT, Problem
from repro.kernels import ops, ref


def unit_index(problem: Problem) -> jnp.ndarray:
    """[U, B] gid gather table for the fused kernel.

    Coordinates decode in gid order, which is unit-major, so the table is
    just arange reshaped -- but the fused layout keeps it an explicit
    gather so padded unit rows can point at the neutral gid 0.
    """
    g = problem.n_units * BLOCKS_PER_UNIT
    return jnp.arange(g, dtype=jnp.int32).reshape(
        problem.n_units, BLOCKS_PER_UNIT)


@functools.partial(jax.jit, static_argnums=(0, 3))
def objectives_from_coords(problem: Problem, bx: jnp.ndarray, by: jnp.ndarray,
                           fused: bool = False
                           ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(wirelength^2, max bbox) from logical block coordinates [..., G].

    `fused=False` (default) is the original two-op path, bit-for-bit;
    `fused=True` routes through `ops.fused_eval` -- one kernel, no
    materialised endpoint/unit tensors between the objectives.
    """
    s, d = jnp.asarray(problem.net_src), jnp.asarray(problem.net_dst)
    w = jnp.asarray(problem.net_w)
    if fused:
        res = ops.fused_eval(bx, by, s, d, w, unit_index(problem))
        return res[..., 0], res[..., 1]
    wl2 = ops.wirelength2(bx[s], by[s], bx[d], by[d], w)
    ux = bx.reshape(problem.n_units, BLOCKS_PER_UNIT)
    uy = by.reshape(problem.n_units, BLOCKS_PER_UNIT)
    bb = ops.maxbbox(ux, uy)
    return wl2, bb


@functools.partial(jax.jit, static_argnums=(0, 2))
def evaluate(problem: Problem, g: G.Genotype, fused: bool = False
             ) -> jnp.ndarray:
    """Genotype -> objectives [2] = (wl^2, max bbox)."""
    bx, by = G.decode(problem, g)
    wl2, bb = objectives_from_coords(problem, bx, by, fused)
    return jnp.stack([wl2, bb])


@functools.partial(jax.jit, static_argnums=(0, 2))
def evaluate_population(problem: Problem, pop: G.Genotype,
                        fused: bool = False) -> jnp.ndarray:
    """Batched genotypes (leading population axis on every leaf) -> [P, 2].

    Fused path: vmap only the decode, then evaluate the whole [P, G]
    coordinate block in a single `ops.fused_eval` call -- outer vmaps
    (slots, islands) stack further batch axes onto the same launch.
    """
    if fused:
        bx, by = jax.vmap(lambda g: G.decode(problem, g))(pop)
        s, d = jnp.asarray(problem.net_src), jnp.asarray(problem.net_dst)
        w = jnp.asarray(problem.net_w)
        return ops.fused_eval(bx, by, s, d, w, unit_index(problem))
    return jax.vmap(lambda g: evaluate(problem, g))(pop)


@functools.partial(jax.jit, static_argnums=(0, 2))
def evaluate_flat_population(problem: Problem, z: jnp.ndarray,
                             fused: bool = False) -> jnp.ndarray:
    """Continuous-encoded population [P, D] -> [P, 2] (CMA-ES / SA path)."""
    if fused:
        bx, by = jax.vmap(
            lambda zz: G.decode(problem, G.from_flat(problem, zz)))(z)
        s, d = jnp.asarray(problem.net_src), jnp.asarray(problem.net_dst)
        w = jnp.asarray(problem.net_w)
        return ops.fused_eval(bx, by, s, d, w, unit_index(problem))
    return jax.vmap(lambda zz: evaluate(problem, G.from_flat(problem, zz)))(z)


def scalarize(objs: jnp.ndarray) -> jnp.ndarray:
    """Single-objective fitness for SA / GA.

    The paper's combined metric is wirelength^2 x max-bbox (Fig. 7a); its log
    is scale-balanced, so SA temperatures mean the same thing for both terms.
    """
    return jnp.log(objs[..., 0] + 1e-9) + jnp.log(objs[..., 1] + 1e-9)


def combined_metric(objs: jnp.ndarray) -> jnp.ndarray:
    """wirelength^2 x max bbox, as plotted in paper Fig. 7a."""
    return objs[..., 0] * objs[..., 1]


@functools.partial(jax.jit, static_argnums=0)
def net_lengths(problem: Problem, g: G.Genotype) -> jnp.ndarray:
    """Per-net Manhattan lengths (post-placement pipelining input)."""
    bx, by = G.decode(problem, g)
    s, d = jnp.asarray(problem.net_src), jnp.asarray(problem.net_dst)
    return ref.net_lengths_ref(bx[s], by[s], bx[d], by[d])


# ------------------------------------------------------------- validation

def validate_placement(problem: Problem, g: G.Genotype) -> Dict[str, bool]:
    """Independent numpy re-check of every constraint (property tests).

    Returns a dict of named boolean checks; all must be True for a legal
    placement.  Deliberately *not* written against the decoder internals:
    it re-derives occupancy from decoded coordinates.
    """
    out: Dict[str, bool] = {}
    for t in G.TYPES:
        geom = problem.geom[t]
        x, y = G._decode_type(geom, g["dist"][t], g["loc"][t])
        x, y = np.asarray(x), np.asarray(y)
        # every block must sit on a column of its type; BRAM parity
        # sub-columns share x, so disambiguate via the row parity
        col_x = np.asarray(geom.col_x)
        col_par = np.asarray(geom.col_parity)
        row = np.round(y / geom.row_pitch).astype(np.int64)
        blk_par = row[:, 0] % geom.site_step
        dist = np.abs(x[:, 0, None] - col_x[None, :])
        dist += 1e9 * (col_par[None, :] != blk_par[:, None])
        col_of = np.argmin(dist, axis=-1)
        out[f"on_column_{t}"] = bool(
            np.allclose(x[:, 0], col_x[col_of], atol=1e-4))
        # cascade adjacency (Eq. 5): successive members step by
        # site_step * row_pitch in RPM rows, same column
        dy = np.diff(y, axis=1)
        step = geom.site_step * geom.row_pitch
        out[f"cascade_{t}"] = bool(np.allclose(dy, step, atol=1e-4))
        out[f"same_col_{t}"] = bool(np.all(np.diff(x, axis=1) == 0.0))
        # exclusivity (Eq. 4): no two chains overlap a site.  Reconstruct
        # integer site indices per (sub)column (parity-aware).
        parity = col_par[col_of]
        site = (row - parity[:, None]) // geom.site_step
        occ = set()
        ok = True
        for c in range(x.shape[0]):
            for s in site[c]:
                key = (int(col_of[c]), int(s))
                if key in occ:
                    ok = False
                occ.add(key)
        out[f"exclusive_{t}"] = ok
        # region (Eq. 3)
        cap = np.asarray(geom.col_cap_chains)[col_of]
        out[f"region_{t}"] = bool(
            np.all(site >= 0)
            and np.all(site < (cap * geom.chain_len)[:, None]))
        # mapping is a permutation
        perm = np.asarray(g["perm"][t])
        out[f"perm_{t}"] = bool(
            np.array_equal(np.sort(perm), np.arange(geom.n_chains)))
    return out


def assert_valid(problem: Problem, g: G.Genotype) -> None:
    checks = validate_placement(problem, g)
    bad = [k for k, v in checks.items() if not v]
    assert not bad, f"illegal placement: {bad}"
