"""Single-objective GA baseline (paper Table I column "GA", ref [37]).

Same composite-genotype operators as NSGA-II (SBX + polynomial mutation on
the real tiers, OX + swap on the mapping permutations), but selection is a
plain fitness tournament on the scalarized objective and survival is
elitist truncation -- the configuration the paper attributes to classic
evolutionary placers, whose crossover weakness NSGA-II/CMA-ES overcome.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict

import jax
import jax.numpy as jnp

from repro.core import genotype as G
from repro.core import nsga2 as N
from repro.core import objectives as O
from repro.fpga.netlist import Problem


@dataclasses.dataclass(frozen=True)
class GAConfig:
    pop_size: int = 64
    crossover_prob: float = 0.9
    sbx_eta: float = 15.0
    mut_eta: float = 20.0
    real_mut_prob: float = 0.1
    perm_swaps: int = 2
    perm_swap_prob: float = 0.6
    elite: int = 4
    fused: bool = False

    def as_nsga2(self) -> N.NSGA2Config:
        return N.NSGA2Config(
            pop_size=self.pop_size, crossover_prob=self.crossover_prob,
            sbx_eta=self.sbx_eta, mut_eta=self.mut_eta,
            real_mut_prob=self.real_mut_prob, perm_swaps=self.perm_swaps,
            perm_swap_prob=self.perm_swap_prob, fused=self.fused)


def init_state(problem: Problem, key: jax.Array, cfg: GAConfig) -> Dict:
    keys = jax.random.split(key, cfg.pop_size)
    pop = jax.vmap(lambda k: G.random_genotype(k, problem))(keys)
    objs = O.evaluate_population(problem, pop, cfg.fused)
    return {"pop": pop, "objs": objs}


def step_impl(problem: Problem, cfg: GAConfig, state: Dict, key: jax.Array
              ) -> Dict:
    """Unjitted body: float config fields may be traced (portfolio)."""
    pop, objs = state["pop"], state["objs"]
    p = cfg.pop_size
    fit = O.scalarize(objs)
    k1, k2, k3 = jax.random.split(key, 3)

    def tourney(k):
        ia = jax.random.randint(k, (p,), 0, p)
        ib = jax.random.randint(jax.random.fold_in(k, 1), (p,), 0, p)
        return jnp.where(fit[ia] <= fit[ib], ia, ib)

    pa, pb = tourney(k1), tourney(k2)

    def take(idx):
        return jax.tree.map(lambda a: a[idx], pop)

    children = jax.vmap(
        lambda k, g1, g2: N._vary_one(k, g1, g2, cfg.as_nsga2()))(
        jax.random.split(k3, p), take(pa), take(pb))
    cobjs = O.evaluate_population(problem, children, cfg.fused)

    # elitist truncation over parents + children by scalar fitness
    allpop = jax.tree.map(lambda a, b: jnp.concatenate([a, b]), pop, children)
    allobjs = jnp.concatenate([objs, cobjs])
    order = jnp.argsort(O.scalarize(allobjs))[:p]
    return {"pop": jax.tree.map(lambda a: a[order], allpop),
            "objs": allobjs[order]}


step = functools.partial(jax.jit, static_argnums=(0, 1))(step_impl)
