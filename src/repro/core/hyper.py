"""Hyperparameter pytree utilities: one compiled program, many configs.

The algorithm configs (``NSGA2Config``, ``GAConfig``, ``CMAESConfig``,
``SAConfig``) are frozen dataclasses whose fields fall into two camps:

  * **static** fields -- ints, bools, strings -- that determine array
    shapes, scan lengths, or Python branches (``pop_size``, ``perm_swaps``,
    ``reduced``, ``schedule``).  These must be baked into the compiled
    program; two configs that differ here need two programs.
  * **traced** fields -- floats -- that are ordinary scalar operands of the
    computation (``sbx_eta``, ``real_mut_prob``, ``t0``, ...).  These can be
    JAX values, which means a *batch axis of configs* can ride a single
    ``vmap``/``jit`` program: the hyperparameter-portfolio trick.

``split_config`` separates the two; the static half becomes a hashable key
(usable with ``jit`` ``static_argnums``), the traced half a ``{name: float}``
dict that vmap/jit treat as a pytree.  ``stack_configs`` batches K configs
that agree on the static half into ``{name: f32[K]}``.  ``tracify`` converts
a config's float fields to f32 scalars so the *same* f32 arithmetic runs
whether a config travels the static path (``evolve.run``) or a portfolio
batch axis -- this is what makes batched and independent runs bit-compatible.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Sequence, Tuple

import jax.numpy as jnp

# (config class, ((name, value), ...)) -- hashable, jit-static-safe
StaticKey = Tuple[type, Tuple[Tuple[str, Any], ...]]


_STATIC_ANNOTATIONS = {"int", "bool", "str"}


def _is_traced_field(f: dataclasses.Field) -> bool:
    """Classify by the *declared* type, not the runtime value: a float
    hyperparameter passed as a Python int (``sbx_eta=20``) must still ride
    the traced path, and an already-traced value has no useful type."""
    t = f.type
    name = t if isinstance(t, str) else getattr(t, "__name__", str(t))
    if name == "float":
        return True
    if name in _STATIC_ANNOTATIONS:
        return False
    raise TypeError(
        f"config field {f.name!r} must be annotated int/bool/str/float "
        f"to ride a portfolio, got {name!r}")


def split_config(cfg) -> Tuple[StaticKey, Dict[str, float]]:
    """Dataclass config -> (hashable static key, traced float dict)."""
    static, traced = [], {}
    for f in dataclasses.fields(cfg):
        v = getattr(cfg, f.name)
        if _is_traced_field(f):
            traced[f.name] = float(v)
        else:
            static.append((f.name, v))
    return (type(cfg), tuple(static)), traced


def merge_config(static_key: StaticKey, traced: Dict[str, Any]):
    """Rebuild a config instance; traced values may be JAX tracers."""
    cls, static = static_key
    return cls(**dict(static), **traced)


def tracify(cfg):
    """Float fields -> f32 scalars (concrete or traced), rest untouched.

    Run inside every jitted driver so constants fold at f32 precision --
    identical arithmetic to the vmapped-portfolio path.
    """
    kwargs = {}
    for f in dataclasses.fields(cfg):
        v = getattr(cfg, f.name)
        kwargs[f.name] = (jnp.asarray(v, jnp.float32)
                          if _is_traced_field(f) else v)
    return type(cfg)(**kwargs)


def stack_configs(cfgs: Sequence) -> Tuple[StaticKey, Dict[str, jnp.ndarray]]:
    """K configs sharing the static half -> (static key, {name: f32[K]}).

    Raises if any member disagrees on a static field: those need their own
    compiled program (a separate portfolio / service pool).
    """
    if not cfgs:
        raise ValueError("empty portfolio")
    splits = [split_config(c) for c in cfgs]
    static_key = splits[0][0]
    for c, (sk, _) in zip(cfgs, splits):
        if sk != static_key:
            raise ValueError(
                "portfolio members must agree on static fields "
                f"(shapes/branches); {c} differs from {cfgs[0]}")
    names = splits[0][1].keys()
    stacked = {n: jnp.asarray([t[n] for _, t in splits], jnp.float32)
               for n in names}
    return static_key, stacked
