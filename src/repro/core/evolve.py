"""Evolution drivers: scanned single-population runs + shard_map islands.

`run` compiles an entire optimization (init + n_gens generations) into one
XLA program via `lax.scan`, recording the per-generation best for the
convergence benchmarks (paper Fig. 7b).  Passing `islands=IslandConfig(P,
G)` dispatches to `core.islands`: P sub-populations with ring champion
migration every G generations, still one program (`islands(P=1)` is
bitwise this module's single-population run).

`run_islands` is the legacy round-synchronous distributed runtime: each
mesh device along the given axis evolves an independent island; every
`gens_per_round` generations the islands exchange their champions over a
ring (`all_gather` + replace-worst).  Migration cadence bounds the
synchronisation frequency -- one slow island delays peers at most once per
round (straggler posture; DESIGN.md SS5).  The same code drives 1 CPU
device and a 512-chip pod slice: only the mesh changes.  New code should
prefer `core.islands` (per-generation cadence, ppermute ring, service
integration); this entry stays for the dry-run cells.
"""
from __future__ import annotations

import functools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import hyper
from repro.core import objectives as O
from repro.fpga.netlist import Problem

from repro.runtime import jaxcompat as jc
from repro.runtime.jaxcompat import make_mesh as _make_mesh
from repro.runtime.jaxcompat import shard_map as _shard_map

ALGOS = ("nsga2", "cmaes", "sa", "ga")


def get_algo(name: str):
    if name == "nsga2":
        from repro.core import nsga2 as m
    elif name == "cmaes":
        from repro.core import cmaes as m
    elif name == "sa":
        from repro.core import annealing as m
    elif name == "ga":
        from repro.core import ga as m
    else:
        raise KeyError(name)
    return m


def state_best_objs(state: Dict) -> jnp.ndarray:
    """Best (wl^2, bbox) of an algorithm state, population or single-point."""
    if "objs" in state and state["objs"].ndim == 2:
        c = O.combined_metric(state["objs"])
        return state["objs"][jnp.argmin(c)]
    if "best_objs" in state:
        return state["best_objs"]
    return state["objs"]


def _run_impl(problem: Problem, algo: str, cfg, key: jax.Array, n_gens: int
              ) -> Tuple[Dict, jnp.ndarray]:
    """Unjitted full run; float config fields may be JAX tracers.

    Float hyperparameters are forced to f32 here so the static path (`run`)
    and the vmapped portfolio path (`core.portfolio`) execute identical
    arithmetic -- batched results match independent runs.
    """
    m = get_algo(algo)
    cfg = hyper.tracify(cfg)
    k_init, k_run = jax.random.split(key)
    state = m.init_state(problem, k_init, cfg)

    def body(st, k):
        st = m.step_impl(problem, cfg, st, k)
        return st, state_best_objs(st)

    state, hist = jax.lax.scan(body, state, jax.random.split(k_run, n_gens))
    return state, hist


_run_single = functools.partial(jax.jit, static_argnums=(0, 1, 2, 4))(
    _run_impl)


def run(problem: Problem, algo: str, cfg, key: jax.Array, n_gens: int,
        islands=None) -> Tuple[Dict, jnp.ndarray]:
    """Full optimization in one program.

    Returns (state, history[n_gens, 2]).  With `islands=IslandConfig(P,
    migrate_every)` the run dispatches to `core.islands.run`: P
    sub-populations with ring champion migration, returning island-stacked
    states [P, ...] and per-island history [n_gens, P, 2] (bitwise the
    single-population result at P=1).
    """
    if islands is None:
        return _run_single(problem, algo, cfg, key, n_gens)
    from repro.core import islands as I
    return I.run(problem, algo, cfg, key, n_gens, islands=islands)


def run_islands(problem: Problem, algo: str, cfg, key: jax.Array,
                rounds: int, gens_per_round: int,
                mesh=None, axis="data") -> Tuple[Dict, jnp.ndarray]:
    """Island-model evolution over mesh axes (population algorithms).

    `axis` may be one mesh axis name or a tuple (islands over the flattened
    product -- the whole-pod configuration).  Returns the stacked per-island
    states and history [rounds, n_islands, 2].
    """
    m = get_algo(algo)
    if mesh is None:
        n = jax.device_count()
        axis = axis if isinstance(axis, str) else "data"
        mesh = _make_mesh((n,), (axis,))
    axes = (axis,) if isinstance(axis, str) else tuple(axis)
    n_islands = 1
    for a in axes:
        n_islands *= mesh.shape[a]
    axis = axes if len(axes) > 1 else axes[0]
    init_keys = jax.random.split(key, n_islands)
    states = jax.vmap(lambda k: m.init_state(problem, k, cfg))(init_keys)
    run_keys = jax.random.split(jax.random.fold_in(key, 7), n_islands)

    @functools.partial(
        _shard_map, mesh=mesh,
        in_specs=(P(axis), P(axis)), out_specs=(P(axis), P(axis)))
    def evolve_shard(state, keys):
        st = jax.tree.map(lambda a: a[0], state)
        my_key = keys[0]

        def round_body(carry, rk):
            st = carry

            def gen(s, k):
                return m.step(problem, cfg, s, k), None

            st, _ = jax.lax.scan(
                gen, st, jax.random.split(rk, gens_per_round))
            # ring migration: adopt the right neighbour's champion
            c = O.combined_metric(st["objs"])
            bi = jnp.argmin(c)
            champ = jax.tree.map(lambda a: a[bi], st["pop"])
            # all_gather over a tuple of axes flattens to one leading dim
            all_champ = jc.all_gather(champ, axes)
            all_objs = jc.all_gather(st["objs"][bi], axes)
            idx = jnp.int32(0)
            for a in axes:
                idx = idx * mesh.shape[a] + jc.axis_index(a)
            nbr = (idx + 1) % n_islands
            mig = jax.tree.map(lambda a: a[nbr], all_champ)
            mig_objs = all_objs[nbr]
            wi = jnp.argmax(c)
            st = dict(st)
            st["pop"] = jax.tree.map(
                lambda a, b: a.at[wi].set(b), st["pop"], mig)
            st["objs"] = st["objs"].at[wi].set(mig_objs)
            return st, state_best_objs(st)

        st, hist = jax.lax.scan(
            round_body, st, jax.random.split(my_key, rounds))
        return (jax.tree.map(lambda a: a[None], st), hist[None])

    states, hist = jax.jit(evolve_shard)(states, run_keys)
    return states, jnp.swapaxes(hist, 0, 1)
