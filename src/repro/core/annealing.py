"""Simulated annealing baseline with the paper's cooling-schedule sweep.

The paper tunes SA over four cooling schedules (Fig. 8) and reports the
hyperbolic schedule as best.  Moves mirror the Opt4J genotype operators:
perturb one distribution gene, perturb one location gene, or swap two
mapping keys (the permutation move); Metropolis acceptance on the scalarized
log(wl^2 x bbox).  Multiple chains run in parallel via vmap -- used both for
statistics and as the parallel-restart baseline.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict

import jax
import jax.numpy as jnp

from repro.core import genotype as G
from repro.core import objectives as O
from repro.fpga.netlist import Problem

SCHEDULES = ("exponential", "linear", "hyperbolic", "adaptive")


@dataclasses.dataclass(frozen=True)
class SAConfig:
    schedule: str = "hyperbolic"
    t0: float = 2.0
    alpha: float = 0.999           # exponential decay
    beta: float = 5e-3             # hyperbolic 1/(1+beta k)
    n_steps: int = 20000           # linear schedule horizon
    move_sigma: float = 0.6
    adapt_target: float = 0.3      # adaptive: target acceptance rate
    fused: bool = False            # route evaluation through ops.fused_eval


def _temperature(cfg: SAConfig, k: jnp.ndarray, t_adapt: jnp.ndarray
                 ) -> jnp.ndarray:
    kf = k.astype(jnp.float32)
    if cfg.schedule == "exponential":
        return cfg.t0 * cfg.alpha ** kf
    if cfg.schedule == "linear":
        return cfg.t0 * jnp.maximum(1.0 - kf / cfg.n_steps, 1e-4)
    if cfg.schedule == "hyperbolic":
        return cfg.t0 / (1.0 + cfg.beta * kf)
    if cfg.schedule == "adaptive":
        return t_adapt
    raise ValueError(cfg.schedule)


def init_state(problem: Problem, key: jax.Array, cfg: SAConfig) -> Dict:
    z = jax.random.normal(key, (problem.continuous_dim,)) * 0.1
    objs = O.evaluate(problem, G.from_flat(problem, z), cfg.fused)
    return {"z": z, "fit": O.scalarize(objs), "objs": objs,
            "k": jnp.int32(0), "t_adapt": jnp.asarray(cfg.t0, jnp.float32),
            "acc_ema": jnp.float32(0.5),
            "best_z": z, "best_objs": objs}


def _move(problem: Problem, key: jax.Array, z: jnp.ndarray,
          sigma: float) -> jnp.ndarray:
    """One random neighbourhood move on the flat genotype."""
    sl = G.flat_split(problem)
    kk = jax.random.split(key, 4)
    kind = jax.random.randint(kk[0], (), 0, 3)

    def perturb(lo, hi, k):
        i = jax.random.randint(k, (), lo, hi)
        return z.at[i].add(jax.random.normal(kk[2]) * sigma)

    def swap_keys(k):
        # permutation move: swap two random keys inside one perm block
        t = jax.random.randint(k, (), 0, 3)
        lo = jnp.array([sl[6][0], sl[7][0], sl[8][0]])[t]
        hi = jnp.array([sl[6][1], sl[7][1], sl[8][1]])[t]
        ki, kj = jax.random.split(kk[2])
        i = lo + jax.random.randint(ki, (), 0, hi - lo)
        j = lo + jax.random.randint(kj, (), 0, hi - lo)
        zi, zj = z[i], z[j]
        return z.at[i].set(zj).at[j].set(zi)

    return jax.lax.switch(kind, [
        lambda: perturb(sl[0][0], sl[2][1], kk[1]),      # distribution tier
        lambda: perturb(sl[3][0], sl[5][1], kk[1]),      # location tier
        lambda: swap_keys(kk[1]),                        # mapping tier
    ])


def step_impl(problem: Problem, cfg: SAConfig, state: Dict, key: jax.Array
              ) -> Dict:
    """Unjitted body: float config fields may be traced (portfolio)."""
    k1, k2 = jax.random.split(key)
    t = _temperature(cfg, state["k"], state["t_adapt"])
    z_new = _move(problem, k1, state["z"], cfg.move_sigma)
    objs_new = O.evaluate(problem, G.from_flat(problem, z_new), cfg.fused)
    fit_new = O.scalarize(objs_new)
    delta = fit_new - state["fit"]
    accept = (delta <= 0) | (
        jax.random.uniform(k2) < jnp.exp(-delta / jnp.maximum(t, 1e-8)))
    z = jnp.where(accept, z_new, state["z"])
    fit = jnp.where(accept, fit_new, state["fit"])
    objs = jnp.where(accept, objs_new, state["objs"])

    acc_ema = 0.99 * state["acc_ema"] + 0.01 * accept.astype(jnp.float32)
    t_adapt = state["t_adapt"] * jnp.where(
        acc_ema > cfg.adapt_target, 0.999, 1.001)

    better = fit < O.scalarize(state["best_objs"])
    return {"z": z, "fit": fit, "objs": objs, "k": state["k"] + 1,
            "t_adapt": t_adapt, "acc_ema": acc_ema,
            "best_z": jnp.where(better, z, state["best_z"]),
            "best_objs": jnp.where(better, objs, state["best_objs"])}


step = functools.partial(jax.jit, static_argnums=(0, 1))(step_impl)


@functools.partial(jax.jit, static_argnums=(0, 1, 3))
def run_chain(problem: Problem, cfg: SAConfig, key: jax.Array,
              n_steps: int, state: Dict) -> Dict:
    """Scan a full chain in one XLA program (keys derived on the fly)."""

    def body(st, k):
        return step(problem, cfg, st, k), st["best_objs"]

    state, hist = jax.lax.scan(body, state, jax.random.split(key, n_steps))
    return {"state": state, "history": hist}
