"""Post-placement pipelining + wire-delay timing model (paper SS III-B, IV-C).

After placement is final, per-net Manhattan wirelengths are exact, so the
right nets can be pipelined to exactly the required depth -- the paper's
argument for post-placement (vs. overprovisioned pre-implementation)
pipelining.  Vivado timing is unavailable here; we use a linear wire-delay
model calibrated to the paper's anchors:

    delay(net)  = K_NS_PER_RPM * manhattan_rpm / (stages + 1)
    period      = T_BASE_NS + max_net delay        (logic + clocking floor)
    f           = min(1/period, F_CEIL)            URAM Fmax ceiling

Anchors: an NSGA-II-optimized VU11P placement reaches ~650 MHz with zero
extra stages and 733 MHz average (Table I); hard-block Fmax caps at 891 MHz.
Register cost of a stage = bus width of the net (netlist bits), times the
full-chip replication factor (the rect is copy-pasted n_rects times).
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import numpy as np

from repro.core import genotype as G
from repro.core import objectives as O
from repro.fpga.netlist import Problem

T_BASE_NS = 1.10       # clk->q + setup + local route floor  (~909 MHz asymptote)
K_NS_PER_RPM = 7.0e-3  # incremental route delay per RPM unit of wirelength
F_CEIL_MHZ = 891.0     # UltraScale+ URAM/DSP hard Fmax


@dataclasses.dataclass(frozen=True)
class PipelineReport:
    freq_mhz: float                # at the chosen pipelining depth
    stages_per_net: np.ndarray     # [N] inserted stages
    total_registers: int           # chip-wide (x n_rects)
    max_net_rpm: float
    depth: int


def frequency_at_depth(problem: Problem, g: G.Genotype, depth: int) -> float:
    """Uniform-depth pipelining: every net gets `depth` stages (Fig. 9)."""
    lens = np.asarray(O.net_lengths(problem, g))
    period = T_BASE_NS + K_NS_PER_RPM * lens.max() / (depth + 1)
    return float(min(1e3 / period, F_CEIL_MHZ))


def registers_at_depth(problem: Problem, depth: int) -> int:
    bits = int(problem.net_bits.sum())
    return bits * depth * problem.n_rects


def auto_pipeline(problem: Problem, g: G.Genotype,
                  target_mhz: float = 650.0) -> PipelineReport:
    """Per-net minimal pipelining to hit `target_mhz` (paper's 650 MHz).

    stages(net) = ceil(K * len / slack) - 1, slack = 1/f_target - T_BASE.
    Nets already fast enough get zero stages -- this is where NSGA-II's small
    bounding boxes save ~6-16% of registers (Table I).
    """
    lens = np.asarray(O.net_lengths(problem, g), np.float64)
    slack_ns = 1e3 / target_mhz - T_BASE_NS
    if slack_ns <= 0:
        raise ValueError(f"target {target_mhz} MHz above model ceiling")
    stages = np.maximum(
        np.ceil(K_NS_PER_RPM * lens / slack_ns) - 1.0, 0.0).astype(np.int64)
    regs = int((stages * problem.net_bits).sum()) * problem.n_rects
    # achieved frequency with those stages
    seg = K_NS_PER_RPM * lens / (stages + 1)
    f = min(1e3 / (T_BASE_NS + seg.max()), F_CEIL_MHZ)
    return PipelineReport(freq_mhz=float(f),
                          stages_per_net=stages,
                          total_registers=regs,
                          max_net_rpm=float(lens.max()),
                          depth=int(stages.max()))


def depth_sweep(problem: Problem, g: G.Genotype, max_depth: int = 4
                ) -> Dict[int, Dict[str, float]]:
    """Fig. 9 data: frequency and register cost per uniform pipeline depth."""
    out = {}
    for d in range(max_depth + 1):
        out[d] = {
            "freq_mhz": frequency_at_depth(problem, g, d),
            "registers": registers_at_depth(problem, d),
        }
    return out
