"""Warm-start state construction: seed any algorithm from a genotype.

Transfer learning (paper SS IV-D, Table II) needs more than `migrate()`:
the migrated champion has to become a *legal initial state* for whatever
algorithm the serving pool runs, at the pool's static shapes.  This module
owns that last mile:

  * `canonicalize`  -- host-side shape normalisation.  A seed may be one
    genotype, a stacked population of K genotypes, or a reduced
    (mapping-only) tuple; it is padded (cyclic tiling) or truncated to the
    pool's static row count so the jitted warm-init program compiles ONCE
    per pool, like every other pool program.  Padded rows are flagged
    `fresh` so the device-side jitter only perturbs copies, never given
    members.
  * `warm_state`    -- device-side (jit/vmap-safe) state builder:
      - nsga2 / ga : population := seed rows + jittered copies (SBX-free
        Gaussian jitter on the real tiers, swap mutations on the mapping
        permutations; row 0 is always the unperturbed seed),
      - cmaes      : mean := flat(seed), sigma := sigma0 * sigma_shrink
        (the paper seeds CMA-ES "with a small sigma" so the search stays
        near the transferred optimum),
      - sa         : chain starts at flat(seed) with the seed's fitness.
  * `member_warm_init` -- the pool-level entry point mirroring
    `portfolio.member_init`: float hyperparameters ride as traced operands,
    so one compiled warm-init serves every job config the pool admits.

Jitter semantics: `jitter == 0` reproduces exact copies (real tiers
unperturbed, no permutation swaps); the default 0.15 matches
`transfer.seed_population`'s historical behaviour.
"""
from __future__ import annotations

from typing import Dict, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import genotype as G
from repro.core import hyper
from repro.core import objectives as O
from repro.fpga.netlist import Problem

# algorithms whose state carries a full population of genotypes
POPULATION_ALGOS = ("nsga2", "ga")

Seed = Union[G.Genotype, Tuple[jnp.ndarray, ...]]


def seed_rows(algo: str, static_key: hyper.StaticKey) -> int:
    """Rows of the canonical seed block for a pool: the static pop_size
    for population algorithms, 1 (the champion) for point algorithms."""
    if algo in POPULATION_ALGOS:
        return dict(static_key[1])["pop_size"]
    return 1


def canonicalize(problem: Problem, init: Seed, n_rows: int
                 ) -> Tuple[G.Genotype, np.ndarray]:
    """Normalise a user-supplied seed to (stacked genotype [n_rows], fresh).

    `init` may be a single genotype, a stacked population (leading axis on
    every leaf), or a reduced mapping-only tuple of permutations (lifted
    via `G.reduced_to_full`).  Stacked populations are ordered best-first
    by combined metric (one host-side evaluation), so truncation to
    `n_rows` keeps the champions and row 0 is always the best member;
    smaller populations tile cyclically, with the tiled copies marked
    `fresh` for device-side jitter.
    """
    if isinstance(init, (tuple, list)):
        init = G.reduced_to_full(problem, tuple(init))
    if not isinstance(init, dict) or set(init) != {"dist", "loc", "perm"}:
        raise TypeError(
            "init_state must be a genotype dict (dist/loc/perm), a stacked "
            f"population of them, or a reduced perm tuple; got {type(init)}")
    leaves = [np.asarray(a) for a in jax.tree.leaves(init)]
    base_ndim = 1  # every genotype leaf is 1-D (per-type vectors)
    stacked = all(a.ndim == base_ndim + 1 for a in leaves)
    single = all(a.ndim == base_ndim for a in leaves)
    if not (stacked or single):
        raise ValueError("seed leaves must all be rank-1 (one genotype) or "
                         "all rank-2 (stacked population)")
    if single:
        pop = jax.tree.map(
            lambda a: np.broadcast_to(np.asarray(a), (n_rows,)
                                      + np.asarray(a).shape).copy(), init)
        fresh = np.arange(n_rows) >= 1
        return pop, fresh
    k = leaves[0].shape[0]
    if any(np.asarray(a).shape[0] != k for a in leaves):
        raise ValueError("stacked seed leaves disagree on population size")
    metric = np.asarray(O.combined_metric(
        O.evaluate_population(problem, jax.tree.map(jnp.asarray, init))))
    order = np.argsort(metric, kind="stable")
    idx = order[np.arange(n_rows) % k]
    pop = jax.tree.map(lambda a: np.asarray(a)[idx], init)
    fresh = np.arange(n_rows) >= k
    return pop, fresh


def jitter_genotype(problem: Problem, key: jax.Array, g: G.Genotype,
                    jitter: jnp.ndarray) -> G.Genotype:
    """One perturbed copy of `g` (jit-safe; `jitter` may be traced).

    Real tiers get Gaussian noise of scale `jitter`; mapping permutations
    get 2 swap mutations with probability scaled so the default
    jitter=0.15 swaps at 0.5 (and jitter=0 never swaps).
    """
    from repro.core import nsga2 as N
    kk = jax.random.split(key, 7)
    swap_prob = jnp.clip(jitter * (0.5 / 0.15), 0.0, 1.0)
    dist = tuple(g["dist"][t]
                 + jax.random.normal(kk[t], g["dist"][t].shape) * jitter
                 for t in range(3))
    loc = tuple(jnp.clip(
        g["loc"][t]
        + jax.random.normal(kk[3 + t], g["loc"][t].shape) * jitter,
        0.0, 1.0) for t in range(3))
    perm = tuple(N._swap_mut(jax.random.fold_in(kk[6], t),
                             g["perm"][t], 2, swap_prob) for t in range(3))
    return {"dist": dist, "loc": loc, "perm": perm}


def _jitter_rows(problem: Problem, key: jax.Array, pop: G.Genotype,
                 fresh: jnp.ndarray, jitter: jnp.ndarray) -> G.Genotype:
    """Perturb exactly the `fresh` rows of a stacked genotype block."""
    n = fresh.shape[0]
    keys = jax.random.split(key, n)
    jittered = jax.vmap(
        lambda k, g: jitter_genotype(problem, k, g, jitter))(keys, pop)

    def pick(a, b):
        m = fresh.reshape((n,) + (1,) * (a.ndim - 1))
        return jnp.where(m, b, a)

    return jax.tree.map(pick, pop, jittered)


def warm_state(problem: Problem, algo: str, cfg, pop: G.Genotype,
               fresh: jnp.ndarray, key: jax.Array,
               jitter: jnp.ndarray, sigma_shrink: jnp.ndarray) -> Dict:
    """Algorithm state seeded from a canonical stacked genotype block.

    `pop`/`fresh` come from `canonicalize`; row 0 is the unperturbed
    champion.  Float config fields may be traced (pool hyperparameters).
    """
    if algo in POPULATION_ALGOS:
        pop = _jitter_rows(problem, key, pop, fresh, jitter)
        if getattr(cfg, "reduced", False):
            perms = pop["perm"]
            from repro.core import nsga2 as N
            return {"pop": perms, "objs": N._eval_reduced(problem, perms)}
        return {"pop": pop, "objs": O.evaluate_population(problem, pop)}

    champ = jax.tree.map(lambda a: a[0], pop)
    z = G.to_flat(problem, champ)
    objs = O.evaluate(problem, champ)
    if algo == "cmaes":
        from repro.core import cmaes as C
        state = C.init_state(problem, key, cfg, mean0=z)
        state["sigma"] = jnp.asarray(cfg.sigma0, jnp.float32) * sigma_shrink
        state["best_objs"] = objs
        state["best_z"] = z
        return state
    if algo == "sa":
        return {"z": z, "fit": O.scalarize(objs), "objs": objs,
                "k": jnp.int32(0),
                "t_adapt": jnp.asarray(cfg.t0, jnp.float32),
                "acc_ema": jnp.float32(0.5),
                "best_z": z, "best_objs": objs}
    raise KeyError(f"warm start not implemented for algo {algo!r}")


def member_warm_init(problem: Problem, algo: str,
                     static_key: hyper.StaticKey,
                     traced: Dict[str, jnp.ndarray], pop: G.Genotype,
                     fresh: jnp.ndarray, jitter: jnp.ndarray,
                     sigma_shrink: jnp.ndarray, key: jax.Array) -> Dict:
    """Pool-level warm init mirroring `portfolio.member_init`: static
    (problem, algo, static_key) bake into the compiled program, float
    hyperparameters + the seed block ride as traced operands -- one
    compile per pool regardless of how many warm jobs arrive."""
    cfg = hyper.tracify(hyper.merge_config(static_key, traced))
    return warm_state(problem, algo, cfg, pop, fresh, key, jitter,
                      sigma_shrink)
