"""Island-model evolution: P sub-populations, ring migration, one program.

The single-population algorithms (`nsga2`, `ga`, `cmaes`, `sa`) cap
quality-per-wallclock at their pop_size: one more generation is one serial
step, however many devices sit idle.  The island model is the classic EA
answer -- P independent sub-populations ("islands") evolve in parallel and
exchange their champions every `migrate_every` generations over a ring --
and on accelerators it is almost free: the island axis is just one more
batch axis.

This module reuses the algorithms' unjitted ``step_impl``s through
`core.hyper`'s static/traced split (exactly like `core.portfolio`), so ONE
jitted program advances every island of a run:

  * `IslandConfig`      -- (n_islands, migrate_every); a frozen hashable
    dataclass, so it rides `jit` static arguments and pool signatures.
  * `member_init` / `member_round` / `member_warm_init` -- the slot-level
    programs mirroring `core.portfolio` / `core.warmstart`, but over
    island-stacked states ``[P, ...]``.  `serve.placement_service` vmaps
    them over its slot axis: an islands pool is just a pool whose static
    signature includes the island config.  Warm seeds land on island 0
    and diffuse to the others via migration.
  * `run` -- the full-run entry (`evolve.run(islands=...)` dispatches
    here).  With more than one visible device and ``P % device_count ==
    0`` the island axis is sharded via `shard_map` (routed through
    `runtime.jaxcompat`), and ring migration crosses shard boundaries
    with a single `ppermute` -- no host round-trip, ever.

Migration is a pure function of the stacked states: island ``i`` adopts
the champion of island ``(i - 1) % P`` (one `jnp.roll` on the stacked
champions, or local roll + boundary `ppermute` when sharded).  Population
states replace their worst member; point states (CMA-ES, SA) adopt the
incoming champion only when it beats their own best, restarting the
mean/chain there.

Determinism: results are a pure function of (config, seed/key, budget,
init_state, island config).  Island keys come from `island_keys`, which
gives island 0 the caller's key *unchanged* when ``P == 1`` -- so
``islands(P=1)`` is bitwise identical to the single-population path, the
degeneracy check CI enforces (`benchmarks.check_bench`:
`islands_match_single_pop`).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import evolve, hyper, portfolio, warmstart
from repro.core import genotype as G
from repro.core import objectives as O
from repro.fpga.netlist import Problem
from repro.runtime import jaxcompat as jc

AXIS = "islands"                   # mesh axis name for the sharded path


@dataclasses.dataclass(frozen=True)
class IslandConfig:
    """Static island topology: baked into compiled programs (and pool
    signatures) exactly like pop_size.  `migrate_every == 0` never
    migrates; `n_islands == 1` is the single-population degeneracy."""
    n_islands: int = 1
    migrate_every: int = 0         # generations between ring migrations

    def __post_init__(self):
        if self.n_islands < 1:
            raise ValueError(f"n_islands must be >= 1, got {self.n_islands}")
        if self.migrate_every < 0:
            raise ValueError(
                f"migrate_every must be >= 0, got {self.migrate_every}")

    @property
    def active(self) -> bool:
        """True when this config actually changes the computation."""
        return self.n_islands > 1


def island_keys(key: jax.Array, n: int) -> jax.Array:
    """[n] per-island PRNG keys.  `n == 1` returns the caller's key
    unchanged (stacked): the P=1 island run consumes the *same* key
    stream as the single-population path -- the bitwise-identity
    contract."""
    if n == 1:
        return key[None]
    return jax.random.split(key, n)


# ----------------------------------------------------------- migration

def champion(state: Dict) -> Tuple[Dict, jnp.ndarray]:
    """(champion payload, its objectives [2]) of ONE island's state.

    Population states ship their best full genotype; point states
    (CMA-ES / SA) ship their flat `best_z`.  The payload pytree is
    identical across islands of a pool, so it rolls/ppermutes as one.
    """
    if "best_z" in state:
        return state["best_z"], state["best_objs"]
    c = O.combined_metric(state["objs"])
    b = jnp.argmin(c)
    return jax.tree.map(lambda a: a[b], state["pop"]), state["objs"][b]


def adopt(state: Dict, champ, champ_objs: jnp.ndarray) -> Dict:
    """One island adopts an incoming champion.

    Population states replace their worst member unconditionally (elitist
    truncation culls it anyway if the local pool is stronger).  Point
    states adopt only on strict improvement, restarting the CMA-ES mean /
    SA chain at the migrant so the search continues from it.
    """
    st = dict(state)
    if "best_z" in state:
        better = (O.combined_metric(champ_objs)
                  < O.combined_metric(state["best_objs"]))
        st["best_z"] = jnp.where(better, champ, state["best_z"])
        st["best_objs"] = jnp.where(better, champ_objs, state["best_objs"])
        if "mean" in state:                                   # cmaes
            st["mean"] = jnp.where(better, champ, state["mean"])
        if "z" in state:                                      # sa
            st["z"] = jnp.where(better, champ, state["z"])
            st["objs"] = jnp.where(better, champ_objs, state["objs"])
            st["fit"] = jnp.where(better, O.scalarize(champ_objs),
                                  state["fit"])
        return st
    w = jnp.argmax(O.combined_metric(state["objs"]))
    st["pop"] = jax.tree.map(lambda a, b: a.at[w].set(b),
                             state["pop"], champ)
    st["objs"] = state["objs"].at[w].set(champ_objs)
    return st


def migrate_ring(state: Dict, axis: Optional[str] = None) -> Dict:
    """Ring migration over island-stacked states ``[L, ...]``: island i
    adopts the champion of island i-1 (mod P, globally).

    Unsharded (`axis=None`): one `jnp.roll` of the stacked champions.
    Inside `shard_map`: local roll + ONE `ppermute` carrying each shard's
    last champion to the next shard's island 0 -- the whole exchange is
    device-to-device, no host round-trip.
    """
    champs, cobjs = jax.vmap(champion)(state)
    if axis is None:
        inc = jax.tree.map(lambda a: jnp.roll(a, 1, axis=0), champs)
        inc_objs = jnp.roll(cobjs, 1, axis=0)
    else:
        n_shards = jc.axis_size(axis)
        perm = jc.ring_perm(n_shards)
        # my last island's champion -> next shard's boundary slot
        bound = jc.ppermute(jax.tree.map(lambda a: a[-1], champs),
                            axis, perm)
        bound_objs = jc.ppermute(cobjs[-1], axis, perm)
        inc = jax.tree.map(
            lambda b, a: jnp.concatenate([b[None], a[:-1]], axis=0),
            bound, champs)
        inc_objs = jnp.concatenate([bound_objs[None], cobjs[:-1]], axis=0)
    return jax.vmap(adopt)(state, inc, inc_objs)


# ------------------------------------------------------ generation loop

def round_impl(problem: Problem, algo: str, icfg: IslandConfig, cfg,
               state: Dict, gen_keys: jax.Array, g0,
               axis: Optional[str] = None) -> Tuple[Dict, jnp.ndarray]:
    """Advance island-stacked states by `len(gen_keys)` generations.

    `gen_keys` is ``[n_gens, L]`` per-island step keys, `g0` the global
    generation count already run (traced: service slots differ).  Ring
    migration fires after every generation g with ``g % migrate_every ==
    0`` -- counted globally, so a service pool stepping `gens_per_step`
    at a time migrates on exactly the same generations as a monolithic
    run.  Returns (state, per-island best objectives ``[n_gens, L, 2]``).
    """
    m = evolve.get_algo(algo)
    migrating = icfg.active and icfg.migrate_every > 0

    def body(carry, ks):
        st, g = carry
        st = jax.vmap(lambda s, k: m.step_impl(problem, cfg, s, k))(st, ks)
        g = g + 1
        if migrating:
            mig = migrate_ring(st, axis)
            do = (g % icfg.migrate_every) == 0
            st = jax.tree.map(lambda a, b: jnp.where(do, b, a), st, mig)
        return (st, g), jax.vmap(evolve.state_best_objs)(st)

    (state, _), hist = jax.lax.scan(body, (state, jnp.int32(g0)), gen_keys)
    return state, hist


def best_over_islands(state: Dict) -> jnp.ndarray:
    """Best (wl^2, bbox) across an island-stacked state (traced-safe)."""
    best = jax.vmap(evolve.state_best_objs)(state)          # [P, 2]
    return best[jnp.argmin(O.combined_metric(best))]


# ------------------------------------------- slot-level member programs
#
# Mirrors of `portfolio.member_init/member_round` and
# `warmstart.member_warm_init` over the island axis: the placement
# service vmaps these over its slot axis, so an islands pool keeps the
# exact serving discipline (static shapes, one compiled step).

def member_init(problem: Problem, algo: str, static_key: hyper.StaticKey,
                icfg: IslandConfig, traced: Dict[str, jnp.ndarray],
                key: jax.Array) -> Dict:
    """Init one slot's island-stacked state ``[P, ...]``."""
    cfg = hyper.tracify(hyper.merge_config(static_key, traced))
    m = evolve.get_algo(algo)
    keys = island_keys(key, icfg.n_islands)
    return jax.vmap(lambda k: m.init_state(problem, k, cfg))(keys)


def member_round(problem: Problem, algo: str, static_key: hyper.StaticKey,
                 icfg: IslandConfig, n_gens: int,
                 traced: Dict[str, jnp.ndarray], state: Dict,
                 key: jax.Array, g0) -> Tuple[Dict, jnp.ndarray]:
    """Advance one slot's islands `n_gens` generations; returns
    (state, best objectives across all islands)."""
    cfg = hyper.tracify(hyper.merge_config(static_key, traced))
    keys = island_keys(key, icfg.n_islands)
    gen_keys = jnp.swapaxes(
        jax.vmap(lambda k: jax.random.split(k, n_gens))(keys), 0, 1)
    state, _ = round_impl(problem, algo, icfg, cfg, state, gen_keys, g0)
    return state, best_over_islands(state)


def member_warm_init(problem: Problem, algo: str,
                     static_key: hyper.StaticKey, icfg: IslandConfig,
                     traced: Dict[str, jnp.ndarray], pop: G.Genotype,
                     fresh: jnp.ndarray, jitter: jnp.ndarray,
                     sigma_shrink: jnp.ndarray, key: jax.Array) -> Dict:
    """Warm-start one slot's islands from a canonical seed block.

    The seed lands on **island 0** (`warmstart.warm_state`, same
    semantics as a non-islands pool); islands 1..P-1 start cold and pick
    the transferred champion up through ring migration -- transfer
    serving (paper SS IV-D) composes with islands for free.
    """
    cold = member_init(problem, algo, static_key, icfg, traced, key)
    cfg = hyper.tracify(hyper.merge_config(static_key, traced))
    keys = island_keys(key, icfg.n_islands)
    warm0 = warmstart.warm_state(problem, algo, cfg, pop, fresh, keys[0],
                                 jitter, sigma_shrink)
    return jax.tree.map(lambda c, w: c.at[0].set(w), cold, warm0)


@functools.partial(jax.jit, static_argnums=(0, 1, 2, 3))
def _vinit(problem, algo, static_key, icfg, traced, keys):
    """[K] slots of island-stacked states in one program (pool fill)."""
    return jax.vmap(
        lambda tr, k: member_init(problem, algo, static_key, icfg, tr, k)
    )(traced, keys)


def best_genotype(problem: Problem, algo: str, state: Dict,
                  cfg=None) -> Tuple[G.Genotype, jnp.ndarray]:
    """Best full genotype + objectives across one slot's islands (host
    side, harvest time): pick the champion island, then delegate to
    `portfolio.best_genotype` on its unstacked state."""
    best = np.asarray(jax.vmap(evolve.state_best_objs)(state))
    i = int(np.argmin(np.asarray(O.combined_metric(jnp.asarray(best)))))
    return portfolio.best_genotype(
        problem, algo, jax.tree.map(lambda a: a[i], state), cfg)


# ------------------------------------------------------- full-run entry

@functools.partial(jax.jit, static_argnums=(0, 1, 2, 3, 5, 6))
def _run(problem: Problem, algo: str, cfg, icfg: IslandConfig,
         key: jax.Array, n_gens: int,
         mesh) -> Tuple[Dict, jnp.ndarray]:
    """One jitted program: init + n_gens generations of P islands.

    Per-island key streams mirror `evolve._run_impl` exactly (split into
    init/run, run split per generation), so P=1 is bitwise the
    single-population run.  With a mesh, the island axis is sharded via
    `shard_map` and migration ppermutes across shard boundaries.
    """
    cfg = hyper.tracify(cfg)
    m = evolve.get_algo(algo)
    keys = island_keys(key, icfg.n_islands)
    halves = jax.vmap(jax.random.split)(keys)               # [P, 2, key]
    k_init, k_run = halves[:, 0], halves[:, 1]
    states = jax.vmap(lambda k: m.init_state(problem, k, cfg))(k_init)
    gen_keys = jnp.swapaxes(
        jax.vmap(lambda k: jax.random.split(k, n_gens))(k_run), 0, 1)

    if mesh is None:
        return round_impl(problem, algo, icfg, cfg, states, gen_keys,
                          jnp.int32(0))

    @functools.partial(
        jc.shard_map, mesh=mesh,
        in_specs=(P(AXIS), P(None, AXIS)),
        out_specs=(P(AXIS), P(None, AXIS)))
    def sharded(st, gk):
        return round_impl(problem, algo, icfg, cfg, st, gk,
                          jnp.int32(0), axis=AXIS)

    return sharded(states, gen_keys)


def run(problem: Problem, algo: str, cfg, key: jax.Array, n_gens: int,
        islands: IslandConfig = IslandConfig(), mesh=None,
        shard: str = "auto") -> Tuple[Dict, jnp.ndarray]:
    """P islands of a full optimization in one program.

    Returns (island-stacked states ``[P, ...]``, per-island history
    ``[n_gens, P, 2]``).  `shard="auto"` shards the island axis across
    all visible devices whenever ``P % device_count == 0`` (pass an
    explicit `mesh` with an ``"islands"`` axis, or ``shard=False``, to
    override); 1 device or an indivisible P falls back to a pure-vmap
    stack of islands -- the same program either way, only the mesh
    changes.
    """
    n = islands.n_islands
    if mesh is None and shard == "auto":
        ndev = jax.device_count()
        if ndev > 1 and n >= ndev and n % ndev == 0:
            mesh = jc.make_mesh((ndev,), (AXIS,))
    if mesh is not None:
        size = int(np.prod([mesh.shape[a] for a in mesh.axis_names
                            if a == AXIS]))
        if AXIS not in mesh.axis_names or n % size != 0:
            raise ValueError(
                f"mesh must carry an {AXIS!r} axis dividing n_islands="
                f"{n}; got axes {mesh.axis_names} shape {dict(mesh.shape)}")
    return _run(problem, algo, cfg, islands, key, n_gens, mesh)
