"""autoshard: the paper's placement EA re-targeted at TPU sharding layouts.

This is the beyond-paper integration (DESIGN.md SS2): the same NSGA-II
machinery that places FPGA hard blocks searches the assignment of *logical
tensor axes to mesh axes*.  The correspondence:

    hard blocks      -> logical axes (batch, width, experts, kv_seq, fsdp)
    columns/sites    -> mesh axes (pod / data / model) + None
    wirelength^2     -> collective seconds   (congestion/link time)
    max bbox         -> peak bytes/device    (critical resource)
    cascade legality -> divisibility (handled downstream by spec_for)
    Vivado run       -> XLA compile (verification only, on the winner)

Genotype: int vector, one gene per decision site, each selecting one option
from that site's menu.  Fitness: `sharding.costmodel.estimate` -- a
microseconds-fast analytical roofline, exactly the paper's
estimate-fast / verify-slow architecture.  Reuses `core.nsga2`'s
non-dominated sorting + crowding unchanged.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core import nsga2
from repro.models.transformer import ArchConfig
from repro.sharding import costmodel as cm
from repro.sharding.logical import Rules, default_rules

# decision sites and their option menus (None = replicate)
SITES: Tuple[Tuple[str, Tuple[object, ...]], ...] = (
    ("batch",     (("data",), ("pod", "data"), ("pod", "data", "model"))),
    ("model_dim", ("model", None, ("data", "model"))),
    ("kv_seq",    ("model", None, ("data", "model"))),
    ("fsdp",      (None, ("data",), ("pod", "data"))),
)


def genotype_to_rules(genes: Sequence[int]) -> Dict[str, object]:
    return {name: opts[g % len(opts)]
            for g, (name, opts) in zip(genes, SITES)}


def rules_to_logical(rules_dict: Dict[str, object],
                     multi_pod: bool) -> Rules:
    """Map an autoshard decision vector onto the model's logical rule table."""
    base = default_rules(multi_pod)
    width = rules_dict.get("model_dim", "model")
    return base.override(
        batch=rules_dict.get("batch"),
        kv_seq=rules_dict.get("kv_seq"),
        q_flat=width, kv_flat=width, heads=width, kv_heads=width,
        mlp=width, experts=width, vocab=width, ssm_inner=width,
    )


@dataclasses.dataclass
class SearchResult:
    best_rules: Dict[str, object]
    best_report: cm.CostReport
    pareto: List[Tuple[Dict[str, object], cm.CostReport]]
    baseline: cm.CostReport
    evaluations: int


def _evaluate(cfg: ArchConfig, shape: str, mesh: cm.MeshShape,
              genes: np.ndarray) -> Tuple[np.ndarray, List[cm.CostReport]]:
    reports = []
    objs = np.empty((len(genes), 2), np.float64)
    for i, g in enumerate(genes):
        r = cm.estimate(cfg, shape, mesh, genotype_to_rules(g))
        reports.append(r)
        # objective 1 = step time bound (collective+compute+memory roofline);
        # objective 2 = peak residency -- wirelength^2 / maxbbox analogues
        objs[i] = (r.collective_s + 0.02 * r.step_s, r.bytes_per_device)
    return objs, reports


def search(cfg: ArchConfig, shape: str, mesh: cm.MeshShape,
           pop_size: int = 32, n_gens: int = 30, seed: int = 0,
           hbm_limit: float = 16e9) -> SearchResult:
    """NSGA-II over sharding genotypes.  Small dims -> numpy operators,
    but ranking/crowding reuse the jitted core.nsga2 machinery."""
    rng = np.random.default_rng(seed)
    n_sites = len(SITES)
    n_opts = np.array([len(o) for _, o in SITES])
    pop = rng.integers(0, n_opts, size=(pop_size, n_sites))
    evals = 0

    baseline = cm.estimate(cfg, shape, mesh,
                           genotype_to_rules([0] * n_sites))

    def penalised(objs, reports):
        out = objs.copy()
        for i, r in enumerate(reports):
            if r.bytes_per_device > hbm_limit:     # infeasible: push off front
                out[i] += 1e6 * (r.bytes_per_device / hbm_limit)
        return out

    objs, reports = _evaluate(cfg, shape, mesh, pop)
    evals += len(pop)
    objs_p = penalised(objs, reports)

    for _ in range(n_gens):
        rank = np.asarray(nsga2.nondominated_rank(jnp.asarray(objs_p)))
        crowd = np.asarray(nsga2.crowding_distance(
            jnp.asarray(objs_p, jnp.float32), jnp.asarray(rank)))
        # binary tournament -> uniform crossover -> site reset mutation
        def pick():
            a, b = rng.integers(0, pop_size, 2)
            if (rank[a], -crowd[a]) <= (rank[b], -crowd[b]):
                return a
            return b

        children = np.empty_like(pop)
        for i in range(pop_size):
            p1, p2 = pop[pick()], pop[pick()]
            mask = rng.random(n_sites) < 0.5
            child = np.where(mask, p1, p2)
            mut = rng.random(n_sites) < (1.0 / n_sites)
            child = np.where(mut, rng.integers(0, n_opts), child)
            children[i] = child
        cobjs, creports = _evaluate(cfg, shape, mesh, children)
        evals += pop_size
        cobjs_p = penalised(cobjs, creports)

        allpop = np.concatenate([pop, children])
        allobjs = np.concatenate([objs_p, cobjs_p])
        allrep = reports + creports
        arank = np.asarray(nsga2.nondominated_rank(jnp.asarray(allobjs)))
        acrowd = np.asarray(nsga2.crowding_distance(
            jnp.asarray(allobjs, jnp.float32), jnp.asarray(arank)))
        order = np.lexsort((-acrowd, arank))[:pop_size]
        pop = allpop[order]
        objs_p = allobjs[order]
        reports = [allrep[i] for i in order]

    # champion: feasible, minimal step-time bound
    feas = [i for i, r in enumerate(reports)
            if r.bytes_per_device <= hbm_limit]
    pool = feas if feas else list(range(len(reports)))
    best_i = min(pool, key=lambda i: reports[i].step_s)
    rank = np.asarray(nsga2.nondominated_rank(jnp.asarray(objs_p)))
    pareto = [(genotype_to_rules(pop[i]), reports[i])
              for i in range(pop_size) if rank[i] == 0]
    return SearchResult(
        best_rules=genotype_to_rules(pop[best_i]),
        best_report=reports[best_i],
        pareto=pareto,
        baseline=baseline,
        evaluations=evals,
    )
