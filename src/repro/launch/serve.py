"""Serving launcher: LM engine or the placement service.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma3-12b --reduced
    PYTHONPATH=src python -m repro.launch.serve --arch gemma3-12b --dry-run
    PYTHONPATH=src python -m repro.launch.serve --placement --device xcvu_test

`--placement` runs the batched placement-as-a-service engine
(`serve.placement_service`): a fixed slot pool continuously batches many
concurrent placement jobs for one FPGA device into a single jitted step.
"""
import argparse
import os


def placement_main(args) -> None:
    import time

    from repro.core import nsga2
    from repro.fpga import device, netlist
    from repro.serve.placement_service import (PlacementService,
                                               make_job_specs)

    prob = netlist.make_problem(device.get_device(args.device))
    base = nsga2.NSGA2Config(pop_size=args.pop)
    svc = PlacementService(prob, base, n_slots=args.slots,
                           gens_per_step=args.gens_per_step)
    specs = make_job_specs(args.requests, args.pop, args.gens)
    t0 = time.perf_counter()
    done = svc.run_jobs(specs)
    dt = time.perf_counter() - t0
    for j in sorted(done, key=lambda j: j.jid):
        print(f"job{j.jid}: {j.gens} gens  wl2={j.best_objs[0]:.3e}  "
              f"bbox={j.best_objs[1]:.0f}  metric={j.metric:.3e}")
    s = svc.stats()
    print(f"{len(done)} jobs in {dt:.2f}s "
          f"({len(done)/dt:.2f} jobs/s, {s['useful_gens']/dt:.1f} gens/s) "
          f"on {args.slots} slots; step compiles: {s['step_compiles']}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--dry-run", action="store_true")
    ap.add_argument("--shape", default="decode_32k")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    # placement-service mode
    ap.add_argument("--placement", action="store_true",
                    help="serve placement jobs instead of an LM")
    ap.add_argument("--device", default="xcvu_test")
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--pop", type=int, default=32)
    ap.add_argument("--gens", type=int, default=64,
                    help="generation budget per placement job")
    ap.add_argument("--gens-per-step", type=int, default=4)
    args = ap.parse_args()

    if args.placement:
        placement_main(args)
        return
    if args.arch is None:
        ap.error("--arch is required unless --placement is given")

    if args.dry_run:
        import subprocess
        import sys
        cmd = [sys.executable, "-m", "repro.launch.dryrun",
               "--arch", args.arch, "--shape", args.shape,
               "--out", "experiments/dryrun"]
        if args.multi_pod:
            cmd.append("--multi-pod")
        raise SystemExit(subprocess.run(cmd, env={
            "PYTHONPATH": "src", **os.environ}).returncode)

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import get_arch, get_reduced
    from repro.models import transformer as T
    from repro.serve.engine import Engine

    cfg = get_reduced(args.arch) if args.reduced else get_arch(args.arch)
    params = T.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    eng = Engine(cfg, params, n_slots=max(2, args.requests // 2),
                 max_len=96, eos_id=-1)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, rng.integers(4, 10))
               .astype(np.int32) for _ in range(args.requests)]
    for i, toks in eng.generate(prompts, max_new=args.max_new).items():
        print(f"req{i}: {toks}")


if __name__ == "__main__":
    main()
