"""Serving launcher: LM engine or the placement service.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma3-12b --reduced
    PYTHONPATH=src python -m repro.launch.serve --arch gemma3-12b --dry-run
    PYTHONPATH=src python -m repro.launch.serve --placement --device xcvu_test
    PYTHONPATH=src python -m repro.launch.serve --placement \
        --device xcvu_test2 --warm-from xcvu_test

`--placement` runs the batched placement-as-a-service engine
(`serve.placement_service`): a fixed slot pool continuously batches many
concurrent placement jobs for one FPGA device into a single jitted step.
`--warm-from BASE` first converges a champion on the BASE device, migrates
it onto `--device` (`core.transfer`), and submits every job transfer-seeded
(`submit(init_state=...)`); jobs then race the migrated champion's metric
warm vs cold to show the Table II speedup direction live.
"""
import argparse
import os


def placement_main(args) -> None:
    import time

    from repro.core import nsga2
    from repro.fpga import device, netlist
    from repro.serve.placement_service import (PlacementService,
                                               make_job_specs)

    prob = netlist.make_problem(device.get_device(args.device))
    base = nsga2.NSGA2Config(pop_size=args.pop)
    svc = PlacementService(prob, base, n_slots=args.slots,
                           gens_per_step=args.gens_per_step)
    specs = make_job_specs(args.requests, args.pop, args.gens)

    if args.warm_from:
        import jax
        import numpy as np

        from repro.core import transfer
        from repro.core import objectives as O

        base_prob = netlist.make_problem(device.get_device(args.warm_from))
        print(f"converging champion on {args.warm_from} "
              f"({args.warm_gens} gens)...")
        champ = transfer.converge_champion(base_prob, jax.random.PRNGKey(0),
                                           2 * args.pop, args.warm_gens)
        g_mig = transfer.migrate(base_prob, prob, champ)
        target = float(O.combined_metric(O.evaluate(prob, g_mig)))
        print(f"migrated champion metric on {args.device}: {target:.3e}; "
              "racing warm vs cold to that target")
        # every spec twice: cold and warm-seeded, chasing the same target
        specs = [dict(s, target=target) for s in specs] + \
                [dict(s, target=target, init_state=g_mig) for s in specs]

    t0 = time.perf_counter()
    done = svc.run_jobs(specs)
    dt = time.perf_counter() - t0
    for j in sorted(done, key=lambda j: j.jid):
        tag = " warm" if j.warm else ""
        print(f"job{j.jid}{tag}: {j.gens} gens  wl2={j.best_objs[0]:.3e}  "
              f"bbox={j.best_objs[1]:.0f}  metric={j.metric:.3e}")
    if args.warm_from:
        cold = [j.gens for j in done if not j.warm]
        warm = [j.gens for j in done if j.warm]
        print(f"gens to target: cold mean {np.mean(cold):.1f}, "
              f"warm mean {np.mean(warm):.1f} "
              f"({np.mean(cold) / max(np.mean(warm), 1e-9):.1f}x fewer)")
    s = svc.stats()
    print(f"{len(done)} jobs in {dt:.2f}s "
          f"({len(done)/dt:.2f} jobs/s, {s['useful_gens']/dt:.1f} gens/s) "
          f"on {args.slots} slots; step compiles: {s['step_compiles']}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--dry-run", action="store_true")
    ap.add_argument("--shape", default="decode_32k")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    # placement-service mode
    ap.add_argument("--placement", action="store_true",
                    help="serve placement jobs instead of an LM")
    ap.add_argument("--device", default="xcvu_test")
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--pop", type=int, default=32)
    ap.add_argument("--gens", type=int, default=64,
                    help="generation budget per placement job")
    ap.add_argument("--gens-per-step", type=int, default=4)
    ap.add_argument("--warm-from", default=None, metavar="DEVICE",
                    help="transfer-seed jobs from a champion converged on "
                         "this base device (e.g. xcvu_test)")
    ap.add_argument("--warm-gens", type=int, default=100,
                    help="generations to converge the base champion")
    args = ap.parse_args()

    if args.placement:
        placement_main(args)
        return
    if args.arch is None:
        ap.error("--arch is required unless --placement is given")

    if args.dry_run:
        import subprocess
        import sys
        cmd = [sys.executable, "-m", "repro.launch.dryrun",
               "--arch", args.arch, "--shape", args.shape,
               "--out", "experiments/dryrun"]
        if args.multi_pod:
            cmd.append("--multi-pod")
        raise SystemExit(subprocess.run(cmd, env={
            "PYTHONPATH": "src", **os.environ}).returncode)

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import get_arch, get_reduced
    from repro.models import transformer as T
    from repro.serve.engine import Engine

    cfg = get_reduced(args.arch) if args.reduced else get_arch(args.arch)
    params = T.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    eng = Engine(cfg, params, n_slots=max(2, args.requests // 2),
                 max_len=96, eos_id=-1)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, rng.integers(4, 10))
               .astype(np.int32) for _ in range(args.requests)]
    for i, toks in eng.generate(prompts, max_new=args.max_new).items():
        print(f"req{i}: {toks}")


if __name__ == "__main__":
    main()
