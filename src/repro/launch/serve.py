"""Serving launcher: reduced-config engine locally, full config via dry-run.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma3-12b --reduced
    PYTHONPATH=src python -m repro.launch.serve --arch gemma3-12b --dry-run
"""
import argparse
import os


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--dry-run", action="store_true")
    ap.add_argument("--shape", default="decode_32k")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()

    if args.dry_run:
        import subprocess
        import sys
        cmd = [sys.executable, "-m", "repro.launch.dryrun",
               "--arch", args.arch, "--shape", args.shape,
               "--out", "experiments/dryrun"]
        if args.multi_pod:
            cmd.append("--multi-pod")
        raise SystemExit(subprocess.run(cmd, env={
            "PYTHONPATH": "src", **os.environ}).returncode)

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import get_arch, get_reduced
    from repro.models import transformer as T
    from repro.serve.engine import Engine

    cfg = get_reduced(args.arch) if args.reduced else get_arch(args.arch)
    params = T.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    eng = Engine(cfg, params, n_slots=max(2, args.requests // 2),
                 max_len=96, eos_id=-1)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, rng.integers(4, 10))
               .astype(np.int32) for _ in range(args.requests)]
    for i, toks in eng.generate(prompts, max_new=args.max_new).items():
        print(f"req{i}: {toks}")


if __name__ == "__main__":
    main()
