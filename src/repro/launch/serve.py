"""Serving launcher: LM engine or the placement service.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma3-12b --reduced
    PYTHONPATH=src python -m repro.launch.serve --arch gemma3-12b --dry-run
    PYTHONPATH=src python -m repro.launch.serve --placement --device xcvu_test
    PYTHONPATH=src python -m repro.launch.serve --placement \
        --device xcvu_test2 --warm-from xcvu_test
    PYTHONPATH=src python -m repro.launch.serve --placement \
        --cache --policy deadline --autoscale
    PYTHONPATH=src python -m repro.launch.serve --placement \
        --islands 4 --migrate-every 4
    PYTHONPATH=src python -m repro.launch.serve --placement --frontend \
        --requests 16 --max-queue 8 --cancel-every 5

`--placement` runs the batched placement-as-a-service engine
(`serve.placement_service`): a fixed slot pool continuously batches many
concurrent placement jobs for one FPGA device into a single jitted step.
`--warm-from BASE` first converges a champion on the BASE device, migrates
it onto `--device` (`core.transfer`), and submits every job transfer-seeded
(`submit(init_state=...)`); jobs then race the migrated champion's metric
warm vs cold to show the Table II speedup direction live.

The control-plane flags route the same workload through
`serve.scheduler.PlacementScheduler` instead of a bare pool:
`--cache [--cache-path P]` attaches a champion store (the second wave of
identical jobs is answered from cache / warm-started), `--policy
{round_robin,priority,deadline}` picks the pool-stepping policy, and
`--autoscale` lets queue depth grow pools along the slot ladder.
`--islands N [--migrate-every G]` makes every slot run N island
sub-populations with ring champion migration (`core.islands`) -- per-job
quality scales with N at the same wallclock step count.

Compile-latency flags (`runtime.compile_cache` / `serve.prewarm`):
`--compile-cache-dir D` (or the `REPRO_COMPILE_CACHE_DIR` environment
variable) turns on jax's persistent compilation cache rooted at D, so a
restarted launcher deserializes its pool programs instead of recompiling;
`--prewarm` attaches the background AOT compiler to the scheduler --
store-predicted pools (`--cache-path` traffic) build off-thread before
their first job, and autoscale ladder sizes pre-compile before `grow()`.

Observability flags (`runtime.telemetry` / `serve.tracing`):
`--metrics-port N` serves Prometheus text exposition on a stdlib HTTP
server at `/metrics` (0 = ephemeral port, printed at startup);
`--trace-file P` enables structured tracing with a JSONL event sink
(also honoured via the `REPRO_TRACE_FILE` environment variable, and
`REPRO_TELEMETRY=1` enables tracing without a sink); `--chrome-trace P`
writes a Perfetto-loadable Chrome trace of every span at exit;
`--metrics-dump P` scrapes the process's own `/metrics` endpoint at exit
and writes the exposition body (CI-friendly with `--metrics-port 0`);
`--profile-dir D` wraps the workload in a `jax.profiler` trace window.

`--frontend` serves the workload through the asyncio front-end
(`serve.frontend.PlacementFrontend`): one concurrent client task per
request submits a `serve.api.JobRequest` and awaits its `JobHandle`,
client 0 streams live progress, `--cancel-every K` cancels every K-th
job mid-flight, and `--max-queue` bounds outstanding admissions
(backpressure).  Composes with every control-plane flag above -- the
front-end owns the stepping thread over the same scheduler.
"""
import argparse
import os


def _telemetry_setup(args):
    """Start the flagged exporters; returns a finalizer to run at exit.

    Order matters: tracing is enabled before any pool/scheduler is built
    so pool.build spans and job.submit events are captured from the first
    request.  The finalizer flushes file sinks and writes the one-shot
    exports (--chrome-trace, --metrics-dump) after the workload is done.
    """
    from repro.runtime import telemetry
    from repro.serve import tracing

    if args.trace_file or args.chrome_trace:
        # a chrome-trace export needs the in-memory span ring even when no
        # JSONL sink was requested
        tracing.enable(jsonl_path=args.trace_file)
    else:
        tracing.maybe_enable_from_env()

    metrics_url = None
    if args.metrics_port is not None:
        _, port = telemetry.start_http_server(args.metrics_port)
        metrics_url = f"http://127.0.0.1:{port}/metrics"
        print(f"metrics: {metrics_url}")

    profiling = False
    if args.profile_dir:
        import jax
        jax.profiler.start_trace(args.profile_dir)
        profiling = True

    def finalize():
        if profiling:
            import jax
            jax.profiler.stop_trace()
            print(f"jax profile: {args.profile_dir}")
        if args.chrome_trace:
            tracing.write_chrome_trace(args.chrome_trace)
            print(f"chrome trace: {args.chrome_trace}")
        if args.metrics_dump:
            # scrape our own endpoint so the dump exercises the HTTP
            # exporter end to end (exposition headers included via GET)
            if metrics_url is not None:
                import urllib.request
                with urllib.request.urlopen(metrics_url, timeout=10) as r:
                    body = r.read().decode("utf-8")
            else:
                body = telemetry.registry().prometheus_text()
            with open(args.metrics_dump, "w", encoding="utf-8") as f:
                f.write(body)
            print(f"metrics dump: {args.metrics_dump}")
        if tracing.enabled():
            tracing.tracer().close_sinks()

    return finalize


def _island_config(args):
    """--islands N [--migrate-every G] -> IslandConfig (None when off)."""
    if args.islands <= 1:
        return None
    from repro.core.islands import IslandConfig
    return IslandConfig(args.islands, args.migrate_every)


def placement_main(args) -> None:
    import time

    from repro.core import nsga2
    from repro.fpga import device, netlist
    from repro.serve.placement_service import (PlacementService,
                                               make_job_specs)

    prob = netlist.make_problem(device.get_device(args.device))
    base = nsga2.NSGA2Config(pop_size=args.pop, fused=args.fused)
    svc = PlacementService(prob, base, n_slots=args.slots,
                           gens_per_step=args.gens_per_step,
                           islands=_island_config(args))
    specs = make_job_specs(args.requests, args.pop, args.gens,
                           fused=args.fused)

    if args.warm_from:
        import jax
        import numpy as np

        from repro.core import transfer
        from repro.core import objectives as O

        base_prob = netlist.make_problem(device.get_device(args.warm_from))
        print(f"converging champion on {args.warm_from} "
              f"({args.warm_gens} gens)...")
        champ = transfer.converge_champion(base_prob, jax.random.PRNGKey(0),
                                           2 * args.pop, args.warm_gens)
        g_mig = transfer.migrate(base_prob, prob, champ)
        target = float(O.combined_metric(O.evaluate(prob, g_mig)))
        print(f"migrated champion metric on {args.device}: {target:.3e}; "
              "racing warm vs cold to that target")
        # every spec twice: cold and warm-seeded, chasing the same target
        specs = [dict(s, target=target) for s in specs] + \
                [dict(s, target=target, init_state=g_mig) for s in specs]

    t0 = time.perf_counter()
    done = svc.run_jobs(specs)
    dt = time.perf_counter() - t0
    for j in sorted(done, key=lambda j: j.jid):
        tag = " warm" if j.warm else ""
        print(f"job{j.jid}{tag}: {j.gens} gens  wl2={j.best_objs[0]:.3e}  "
              f"bbox={j.best_objs[1]:.0f}  metric={j.metric:.3e}")
    if args.warm_from:
        cold = [j.gens for j in done if not j.warm]
        warm = [j.gens for j in done if j.warm]
        print(f"gens to target: cold mean {np.mean(cold):.1f}, "
              f"warm mean {np.mean(warm):.1f} "
              f"({np.mean(cold) / max(np.mean(warm), 1e-9):.1f}x fewer)")
    s = svc.stats()
    isl = (f", {s['n_islands']} islands/slot "
           f"(migrate every {s['migrate_every']})"
           if s["n_islands"] > 1 else "")
    print(f"{len(done)} jobs in {dt:.2f}s "
          f"({len(done)/dt:.2f} jobs/s, {s['useful_gens']/dt:.1f} gens/s) "
          f"on {args.slots} slots{isl}; step compiles: "
          f"{s['step_compiles']}")


def control_plane_main(args) -> None:
    """Placement traffic through the scheduler control plane: champion
    cache (`--cache`), stepping policy (`--policy`), pool autoscaling
    (`--autoscale`) -- two waves of the same workload so cache effects are
    visible live."""
    import time

    from repro.core import nsga2
    from repro.serve.api import JobRequest
    from repro.serve.champion_store import ChampionStore
    from repro.serve.placement_service import make_job_specs
    from repro.serve.scheduler import PlacementScheduler

    store = (ChampionStore(path=args.cache_path)
             if (args.cache or args.cache_path) else None)
    icfg = _island_config(args)
    sch = PlacementScheduler(n_slots=args.slots,
                             gens_per_step=args.gens_per_step,
                             policy=args.policy, store=store,
                             autoscale=args.autoscale,
                             prewarm=args.prewarm)
    if args.prewarm and store is not None:
        # a persisted store carries its historical signature traffic:
        # start compiling the predicted working set before the first job
        keys = sch.prewarm_predicted()
        if keys:
            print(f"prewarming {len(keys)} store-predicted pool(s) "
                  "in the background...")

    if args.warm_from:
        # control-plane spelling of --warm-from: converge a champion on
        # the base device and seed the STORE with it -- every job on
        # --device then warm-starts via signature discovery, no caller
        # init_state needed
        if store is None:
            raise SystemExit("--warm-from with a control-plane flag needs "
                             "--cache (the champion rides in the store)")
        import jax

        from repro.core import transfer
        from repro.core import objectives as O

        base_prob = sch.problem(args.warm_from)
        print(f"seeding store from {args.warm_from} "
              f"({args.warm_gens} gens)...")
        champ = transfer.converge_champion(base_prob, jax.random.PRNGKey(0),
                                           2 * args.pop, args.warm_gens)
        objs = O.evaluate(base_prob, champ)
        store.put(base_prob, champ, float(O.combined_metric(objs)), objs,
                  provenance={"source": "warm_from", "algo": "nsga2"})

    def wave(tag, specs, **kw):
        t0 = time.perf_counter()
        jids = [sch.submit_request(JobRequest(
                    device=args.device, cfg=s["cfg"], seed=s["seed"],
                    budget=s["budget"], target=s.get("target"),
                    islands=icfg, **kw))
                for s in specs]
        done = {j.jid: j for j in sch.run_all()}
        dt = time.perf_counter() - t0
        for jid in jids:
            j, r = done[jid], done[jid].result
            how = ("cache-hit" if j.cached else
                   "warm" if j.warm_from_cache else "cold")
            print(f"  job{jid} [{how:9s}] {r.gens:3d} gens  "
                  f"metric={r.metric:.3e}")
        print(f"  {tag}: {len(jids)} jobs in {dt:.2f}s")
        return done

    specs = make_job_specs(args.requests, args.pop, args.gens,
                           fused=args.fused)
    if args.policy == "deadline":
        # the last-submitted job is the most urgent; EDF picks which POOL
        # steps, so the urgent job gets its own pool (half the pop size)
        # and is served ahead of the earlier-submitted bulk pool
        print("wave 1 (deadline policy: last job has the tight deadline)")
        urgent_cfg = nsga2.NSGA2Config(pop_size=max(2, args.pop // 2),
                                       fused=args.fused)
        for s in specs:
            sch.submit_request(JobRequest(
                device=args.device, cfg=s["cfg"], seed=s["seed"],
                budget=s["budget"], deadline=1e9, islands=icfg))
        ujid = sch.submit_request(JobRequest(
            device=args.device, cfg=urgent_cfg, seed=0,
            budget=args.gens, deadline=1.0, islands=icfg))
        order = [j.jid for j in sch.run_all()]
        print(f"  urgent job finished {order.index(ujid) + 1}/{len(order)}")
    else:
        print("wave 1 (cold)")
        wave("wave 1", specs)
    if store is not None:
        # target against the serving device's OWN champion when it has
        # one (metrics don't compare across devices), else the best entry
        own = store.get(sch.problem(args.device).signature)
        best = (own.metric if own is not None
                else min(e.metric for e in store.entries()))
        print(f"wave 2 (served against cache, target={best:.3e})")
        wave("wave 2", [dict(s, target=best * 1.001) for s in specs])
        print(f"  cache: {store.stats()}")
        if args.cache_path:
            print(f"  persisted {len(store)} champions -> "
                  f"{store.save(args.cache_path)}")
    s = sch.stats()
    if args.autoscale:
        print(f"autoscale events (pool, old, new): {s['autoscale_events']}")
    print(f"{s['n_pools']} pools, policy={s['policy']}; per-pool sizes/"
          f"compiles: " + ", ".join(
              f"{ps['sizes']}x{ps['step_compiles']}"
              for ps in s["pools"].values()))


def frontend_main(args) -> None:
    """--frontend: the same placement workload, served through the asyncio
    front-end -- N concurrent client coroutines, mixed priorities, optional
    mid-flight cancellations, live progress for client 0, and per-client
    submit->result latency percentiles at the end."""
    import asyncio
    import time

    import numpy as np

    from repro.serve.api import JobRequest
    from repro.serve.champion_store import ChampionStore
    from repro.serve.frontend import PlacementFrontend
    from repro.serve.placement_service import make_job_specs
    from repro.serve.scheduler import PlacementScheduler

    store = (ChampionStore(path=args.cache_path)
             if (args.cache or args.cache_path) else None)
    sch = PlacementScheduler(n_slots=args.slots,
                             gens_per_step=args.gens_per_step,
                             policy=args.policy, store=store,
                             autoscale=args.autoscale,
                             prewarm=args.prewarm)
    icfg = _island_config(args)
    specs = make_job_specs(args.requests, args.pop, args.gens,
                           fused=args.fused)
    lat: list = []

    async def client(fe, i, spec):
        req = JobRequest(device=args.device, cfg=spec["cfg"],
                         seed=spec["seed"], budget=spec["budget"],
                         priority=float(i % 3), islands=icfg)
        t0 = time.perf_counter()
        handle = await fe.submit(req)
        if i == 0:                         # one client streams progress
            async for u in handle.progress():
                eta = f"  eta={u.eta_s:.1f}s" if u.eta_s else ""
                print(f"  job{u.jid} progress: gen {u.gens}/{u.budget}"
                      f"  metric={u.metric:.3e}{eta}")
        if args.cancel_every and (i + 1) % args.cancel_every == 0:
            handle.cancel()
            try:
                await handle.wait()
            except Exception:              # noqa: BLE001 -- demo client
                pass
            print(f"  client{i:2d}: [{handle.status.value}]")
            return
        r = await handle.wait()
        lat.append(time.perf_counter() - t0)
        print(f"  client{i:2d}: job{handle.jid} {r.gens:3d} gens  "
              f"metric={r.metric:.3e}")

    async def run():
        t0 = time.perf_counter()
        async with PlacementFrontend(sch, max_queue=args.max_queue) as fe:
            await asyncio.gather(*[client(fe, i, s)
                                   for i, s in enumerate(specs)])
            stats = fe.stats()
        return stats, time.perf_counter() - t0

    stats, dt = asyncio.run(run())
    if lat:
        p50, p99 = np.percentile(np.array(lat) * 1e3, [50, 99])
        print(f"submit->result latency: p50={p50:.0f}ms p99={p99:.0f}ms")
    print(f"{stats['completed']} done / {stats['cancelled']} cancelled / "
          f"{stats['failed']} failed in {dt:.2f}s "
          f"({stats['completed'] / dt:.2f} jobs/s); backpressure waits: "
          f"{stats['backpressure_waits']}")
    fleet = stats["fleet"]
    print(f"{fleet['n_pools']} pool(s); per-pool sizes/compiles: "
          + ", ".join(f"{p['sizes']}x{p['step_compiles']}"
                      for p in fleet["pools"].values()))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--dry-run", action="store_true")
    ap.add_argument("--shape", default="decode_32k")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    # placement-service mode
    ap.add_argument("--placement", action="store_true",
                    help="serve placement jobs instead of an LM")
    ap.add_argument("--device", default="xcvu_test")
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--pop", type=int, default=32)
    ap.add_argument("--gens", type=int, default=64,
                    help="generation budget per placement job")
    ap.add_argument("--gens-per-step", type=int, default=4)
    ap.add_argument("--fused", action="store_true",
                    help="evaluate through the fused Pallas pipeline "
                         "(kernels.fused_eval); static pool identity")
    ap.add_argument("--islands", type=int, default=1, metavar="N",
                    help="island sub-populations per slot (core.islands); "
                         "1 = single-population pools")
    ap.add_argument("--migrate-every", type=int, default=4, metavar="G",
                    help="generations between ring champion migrations "
                         "inside an islands slot")
    ap.add_argument("--warm-from", default=None, metavar="DEVICE",
                    help="transfer-seed jobs from a champion converged on "
                         "this base device (e.g. xcvu_test)")
    ap.add_argument("--warm-gens", type=int, default=100,
                    help="generations to converge the base champion")
    # control-plane flags (route through serve.scheduler)
    ap.add_argument("--cache", action="store_true",
                    help="attach a champion store: repeat jobs are served "
                         "from cache / warm-started by signature")
    ap.add_argument("--cache-path", default=None, metavar="JSON",
                    help="persist the champion store to this JSON file")
    ap.add_argument("--policy", default="round_robin",
                    choices=("round_robin", "priority", "deadline"),
                    help="pool stepping policy (serve.policy)")
    ap.add_argument("--autoscale", action="store_true",
                    help="grow pools along the slot ladder on queue depth")
    ap.add_argument("--compile-cache-dir", default=None, metavar="DIR",
                    help="enable jax's persistent compilation cache rooted "
                         "here (also honoured via the "
                         "REPRO_COMPILE_CACHE_DIR environment variable): a "
                         "restarted process deserializes its pool programs "
                         "instead of recompiling")
    ap.add_argument("--prewarm", action="store_true",
                    help="background AOT pool compiler (serve.prewarm): "
                         "store-predicted pools and autoscale ladder sizes "
                         "compile off the stepping loop")
    # observability flags (runtime.telemetry / serve.tracing)
    ap.add_argument("--metrics-port", type=int, default=None, metavar="N",
                    help="serve Prometheus text exposition at "
                         "http://127.0.0.1:N/metrics (0 = pick an "
                         "ephemeral port, printed at startup)")
    ap.add_argument("--trace-file", default=None, metavar="JSONL",
                    help="enable structured tracing (serve.tracing) with "
                         "a JSONL event sink at this path; also honoured "
                         "via the REPRO_TRACE_FILE environment variable")
    ap.add_argument("--chrome-trace", default=None, metavar="JSON",
                    help="write a Perfetto-loadable Chrome trace of all "
                         "spans at exit (implies tracing on)")
    ap.add_argument("--metrics-dump", default=None, metavar="TXT",
                    help="at exit, scrape this process's own /metrics "
                         "endpoint (or render the registry directly when "
                         "--metrics-port is absent) and write the body")
    ap.add_argument("--profile-dir", default=None, metavar="DIR",
                    help="wrap the workload in a jax.profiler trace "
                         "window written under this directory")
    # async front-end flags (route through serve.frontend)
    ap.add_argument("--frontend", action="store_true",
                    help="serve through the asyncio front-end "
                         "(serve.frontend): concurrent clients, streaming "
                         "progress, cancellation, backpressure")
    ap.add_argument("--max-queue", type=int, default=32,
                    help="front-end admission bound: submits beyond this "
                         "many outstanding jobs await a free credit")
    ap.add_argument("--cancel-every", type=int, default=0, metavar="K",
                    help="with --frontend, cancel every K-th job "
                         "mid-flight (0 = never)")
    args = ap.parse_args()

    if args.placement:
        from repro.runtime import compile_cache
        enabled = compile_cache.maybe_enable_from_env(args.compile_cache_dir)
        if enabled:
            print(f"persistent compilation cache: {enabled} "
                  f"({compile_cache.cache_salt()})")
        finalize = _telemetry_setup(args)
        try:
            if args.frontend:
                frontend_main(args)
            elif (args.cache or args.cache_path or args.autoscale
                  or args.prewarm or args.policy != "round_robin"):
                control_plane_main(args)
            else:
                placement_main(args)
        finally:
            finalize()
        return
    if args.arch is None:
        ap.error("--arch is required unless --placement is given")

    if args.dry_run:
        import subprocess
        import sys
        cmd = [sys.executable, "-m", "repro.launch.dryrun",
               "--arch", args.arch, "--shape", args.shape,
               "--out", "experiments/dryrun"]
        if args.multi_pod:
            cmd.append("--multi-pod")
        raise SystemExit(subprocess.run(cmd, env={
            "PYTHONPATH": "src", **os.environ}).returncode)

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import get_arch, get_reduced
    from repro.models import transformer as T
    from repro.serve.engine import Engine

    cfg = get_reduced(args.arch) if args.reduced else get_arch(args.arch)
    params = T.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    eng = Engine(cfg, params, n_slots=max(2, args.requests // 2),
                 max_len=96, eos_id=-1)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, rng.integers(4, 10))
               .astype(np.int32) for _ in range(args.requests)]
    for i, toks in eng.generate(prompts, max_new=args.max_new).items():
        print(f"req{i}: {toks}")


if __name__ == "__main__":
    main()
