"""Distributed training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch yi-6b \
        [--steps 100] [--ckpt-dir ...] [--dry-run]

On real hardware this drives the production mesh; on this CPU container use
--dry-run (lower+compile only, same path as launch.dryrun) or a reduced
config (--reduced) for an actually-executing loop.  Fault tolerance knobs
(checkpoint cadence, failure injection) ride on train.trainer.
"""
import argparse
import os


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--reduced", action="store_true",
                    help="run the family-preserving reduced config")
    ap.add_argument("--dry-run", action="store_true",
                    help="lower+compile the full config on the 16x16 mesh")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    args = ap.parse_args()

    if args.dry_run:
        # delegate to the dry-run in a fresh interpreter: the 512-device
        # flag must be set before jax initialises
        import subprocess
        import sys
        cmd = [sys.executable, "-m", "repro.launch.dryrun",
               "--arch", args.arch, "--shape", "train_4k",
               "--out", "experiments/dryrun"]
        if args.multi_pod:
            cmd.append("--multi-pod")
        raise SystemExit(subprocess.run(cmd, env={
            "PYTHONPATH": "src", **os.environ}).returncode)

    import jax.numpy as jnp

    from repro.configs import get_arch, get_reduced
    from repro.data.pipeline import DataConfig
    from repro.train import optimizer as opt
    from repro.train.trainer import Trainer, TrainerConfig

    cfg = get_reduced(args.arch) if args.reduced else get_arch(args.arch)
    tr = Trainer(
        cfg,
        opt.OptConfig(lr=3e-4, warmup_steps=min(20, args.steps // 5 + 1),
                      total_steps=args.steps),
        TrainerConfig(steps=args.steps, ckpt_every=max(args.steps // 4, 10),
                      ckpt_dir=args.ckpt_dir, log_every=10,
                      param_dtype=jnp.float32),
        DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                   global_batch=args.batch),
    )
    for h in tr.run_with_recovery():
        print(h)


if __name__ == "__main__":
    main()
