import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape) on the production
meshes, extract memory/cost/collective numbers for the roofline analysis.

MUST be the first import in its process: the two lines above force 512
placeholder host devices BEFORE jax locks the device count.  Never set that
flag globally -- smoke tests and benches see the real single CPU device.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch yi-6b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] \
        [--out experiments/dryrun]

Per cell it writes <out>/<arch>__<shape>__<mesh>.json with:
    memory_analysis (per-device bytes), cost_analysis (flops/bytes),
    collective bytes by kind (HLO parse, loop trip counts included),
    the rules used, timing, and the roofline terms.
"""
import argparse   # noqa: E402
import dataclasses  # noqa: E402
import json       # noqa: E402
import time       # noqa: E402
import traceback  # noqa: E402
from typing import Any, Dict, Optional  # noqa: E402

import jax        # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import base as cbase  # noqa: E402
from repro.configs.base import SHAPES, input_specs, shape_applicable  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models import transformer as T  # noqa: E402
from repro.sharding import costmodel as cm  # noqa: E402
from repro.sharding import hloparse, logical  # noqa: E402
from repro.train import optimizer as opt  # noqa: E402
from repro.train.train_step import make_train_step  # noqa: E402

# v5e constants (assignment)
PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9


def _sds_with_sharding(tree, axes_tree, mesh, rules, zero1: bool = False):
    """Attach NamedShardings to a ShapeDtypeStruct tree via logical axes.

    zero1=True additionally shards the first still-replicated dim over the
    batch axes (ZeRO-1 optimizer-state partitioning): GSPMD then materialises
    the reduce-scatter/all-gather pair around the update automatically.
    """

    def one(sds, axes):
        spec = logical.spec_for(axes, sds.shape, mesh, rules)
        if zero1:
            parts = list(spec)
            batch_ax = rules.get("batch") or ()
            batch_ax = ((batch_ax,) if isinstance(batch_ax, str)
                        else tuple(batch_ax))
            used = {a for p in parts if p
                    for a in ((p,) if isinstance(p, str) else p)}
            free = tuple(a for a in batch_ax
                         if a in mesh.shape and a not in used)
            if free:
                size = 1
                for a in free:
                    size *= mesh.shape[a]
                for i, p in enumerate(parts):
                    if p is None and sds.shape[i] % size == 0 \
                            and sds.shape[i] >= size:
                        parts[i] = free if len(free) > 1 else free[0]
                        break
                spec = jax.sharding.PartitionSpec(*parts)
        return jax.ShapeDtypeStruct(
            sds.shape, sds.dtype,
            sharding=jax.sharding.NamedSharding(mesh, spec))

    return jax.tree.map(one, tree, axes_tree,
                        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


def _batch_axes_tree(batch_sds: Dict[str, jax.ShapeDtypeStruct]):
    out = {}
    for k, v in batch_sds.items():
        if k == "frontend_embeds":
            out[k] = ("batch", None, None)
        elif v.ndim == 2:
            out[k] = ("batch", None)
        else:
            out[k] = ("batch",)
    return out


def _cache_axes(cfg: T.ArchConfig, caches_sds):
    """Logical axes for the stacked cache tree (leading periods dim)."""
    def axes_for(leaf):
        nd = leaf.ndim
        if nd == 5:                  # [periods, B, Hkv, T, dh] attention
            return (None, "batch", None, "kv_seq", None)
        if nd == 4:                  # mamba h [periods,B,di,ds] / rwkv S...
            return (None, "batch", "ssm_inner", None)
        if nd == 3:
            return (None, "batch", None)
        if nd == 2:
            return (None, "batch")
        return (None,) * nd

    return jax.tree.map(axes_for, caches_sds,
                        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


def _rwkv_cache_axes(leaf):
    return None


def build_lowerable(cfg: T.ArchConfig, shape_name: str, mesh, rules,
                    dtype=jnp.bfloat16):
    """Returns (fn, example_args_SDS, donate) for the cell's step."""
    ss = SHAPES[shape_name]
    paxes = T.param_axes(cfg)
    params_sds = jax.eval_shape(
        lambda: T.init_params(cfg, jax.random.PRNGKey(0), dtype))
    # FSDP: when TP-sharded weights still exceed ~4 GiB/device, shard the
    # remaining replicated dim over the batch axes (GSPMD all-gathers per
    # layer inside the scan -- standard FSDP semantics)
    tp = mesh.shape.get("model", 1)
    fsdp = (ss.kind == "train"
            and cfg.param_count() * 2 / tp > 4e9)
    if os.environ.get("REPRO_FSDP_PARAMS") == "1":
        fsdp = True        # SSPerf lever: weight-gathered decode/prefill
    params_sds = _sds_with_sharding(params_sds, paxes, mesh, rules,
                                    zero1=fsdp)

    if ss.kind == "train":
        ocfg = opt.OptConfig()
        opt_sds = jax.eval_shape(opt.init, params_sds)
        opt_axes = {
            "master": paxes, "m": paxes, "v": paxes, "step": (),
        }
        # ZeRO-1: fp32 master/m/v shard over the batch axes on top of TP
        opt_sds = _sds_with_sharding(opt_sds, opt_axes, mesh, rules,
                                     zero1=True)
        batch_sds = input_specs(cfg, shape_name)
        batch_sds = _sds_with_sharding(batch_sds, _batch_axes_tree(batch_sds),
                                       mesh, rules)
        n_micro = _auto_microbatch(cfg, ss, mesh, rules)
        step = make_train_step(cfg, ocfg, n_micro)
        return step, (params_sds, opt_sds, batch_sds), (0, 1), n_micro

    if ss.kind == "prefill":
        batch_sds = input_specs(cfg, shape_name)
        batch_sds = _sds_with_sharding(batch_sds, _batch_axes_tree(batch_sds),
                                       mesh, rules)
        max_len = ss.seq_len + cfg.n_frontend_tokens + 128

        def prefill_step(params, batch):
            return T.prefill(params, cfg, batch["tokens"], max_len,
                             batch.get("frontend_embeds"))

        return prefill_step, (params_sds, batch_sds), (), 1

    # decode
    b = ss.global_batch
    max_len = ss.seq_len
    caches_sds = jax.eval_shape(
        lambda: T.init_caches(cfg, b, max_len, dtype))
    caches_sds = _sds_with_sharding(
        caches_sds, _cache_axes(cfg, caches_sds), mesh, rules)
    io_sds = input_specs(cfg, shape_name)
    io_sds = _sds_with_sharding(
        io_sds, {"token": ("batch",), "cache_len": ("batch",)}, mesh, rules)

    def serve_step(params, token, caches, cache_len):
        return T.decode_step(params, cfg, token, caches, cache_len)

    return (serve_step,
            (params_sds, io_sds["token"], caches_sds, io_sds["cache_len"]),
            (2,), 1)


def _auto_microbatch(cfg, ss, mesh, rules) -> int:
    """Pick the smallest grad-accumulation factor whose remat activation
    stack fits the HBM budget (recorded per-cell; a SSPerf lever)."""
    dp = 1
    batch_ax = rules.get("batch") or ()
    batch_ax = (batch_ax,) if isinstance(batch_ax, str) else tuple(batch_ax)
    for a in batch_ax:
        if a in mesh.shape:
            dp *= mesh.shape[a]
    tp = mesh.shape.get("model", 1)
    tok_loc = ss.global_batch * ss.seq_len / max(dp, 1)
    per_tok = cfg.d_model * 2 * cfg.n_layers               # remat stack, bf16
    per_tok += 3 * 4 * cfg.vocab / max(tp, 1)              # f32 logits + grad
    if cfg.moe_every:                                      # dispatch buffers
        per_tok += cfg.top_k * cfg.d_model * 2 * 4
    if cfg.rwkv or cfg.attn_every:                         # ssm chunk states
        per_tok *= 1.5
    budget = 5.5e9
    need = tok_loc * per_tok
    best = 1
    for n in (1, 2, 4, 8, 16, 32):
        if ss.global_batch % n == 0 and ss.global_batch // n >= dp:
            best = n
            if need / n <= budget:
                return n
    return best


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             rules_override: Optional[Dict[str, Any]] = None,
             save_dir: Optional[str] = None,
             verbose: bool = True) -> Dict[str, Any]:
    if arch == "vu_systolic":
        return run_ea_cell(multi_pod, save_dir, verbose)
    cfg = cbase.get_arch(arch)
    mesh_tag = "pod2x16x16" if multi_pod else "pod16x16"
    out: Dict[str, Any] = {
        "arch": arch, "shape": shape_name, "mesh": mesh_tag,
        "params_b": cfg.param_count(),
    }
    if not shape_applicable(cfg, shape_name):
        out["status"] = "skipped"
        out["reason"] = ("long_500k requires sub-quadratic attention; "
                         "skip documented in DESIGN.md SSArch-applicability")
        _save(out, save_dir)
        return out

    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = logical.default_rules(multi_pod)
    if shape_name == "long_500k":
        # B=1: the data axis is idle for batch; spend it on KV sequence
        rules = rules.override(kv_seq=("data", "model"), batch=None)
    if rules_override:
        rules = rules.override(**rules_override)
    out["rules"] = {k: v for k, v in rules.table}

    t0 = time.time()
    try:
        with logical.activate(mesh, rules):
            built = build_lowerable(cfg, shape_name, mesh, rules)
            fn, args, donate, n_micro = built
            lowered = jax.jit(fn, donate_argnums=donate).lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
        ma = compiled.memory_analysis()
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):      # jax <= 0.4.x: list per device
            ca = ca[0] if ca else {}
        text = compiled.as_text()
        walk = hloparse.analyze(text)      # trip-count-aware per-device walk
        chips = 512 if multi_pod else 256

        flops_dev = float(walk["flops"])             # dot flops, loop-scaled
        flops_dev_xla = float(ca.get("flops", 0.0))  # raw (loops counted 1x)
        bytes_dev = float(walk["traffic_bytes"])
        coll_dev = float(walk["total"])

        ss = SHAPES[shape_name]
        model_fl = cm.model_flops_per_step(cfg, ss)

        terms = {
            "compute_s": flops_dev / PEAK_FLOPS,
            "memory_s": bytes_dev / HBM_BW,
            "collective_s": coll_dev / ICI_BW,
        }
        out.update(
            status="ok",
            n_micro=n_micro,
            lower_s=round(t_lower, 2), compile_s=round(t_compile, 2),
            memory={
                "argument_bytes": ma.argument_size_in_bytes,
                "output_bytes": ma.output_size_in_bytes,
                "temp_bytes": ma.temp_size_in_bytes,
                "alias_bytes": ma.alias_size_in_bytes,
                "peak_estimate_bytes": (ma.argument_size_in_bytes
                                        + ma.temp_size_in_bytes
                                        + ma.output_size_in_bytes
                                        - ma.alias_size_in_bytes),
            },
            cost={"flops_per_device": flops_dev,
                  "flops_per_device_xla_raw": flops_dev_xla,
                  "bytes_per_device": bytes_dev},
            collectives={k: float(walk[k])
                         for k in hloparse.COLLECTIVES + ("total",)},
            roofline=dict(
                terms,
                dominant=max(terms, key=terms.get),
                model_flops=model_fl,
                hlo_flops_global=flops_dev * chips,
                useful_ratio=(model_fl / (flops_dev * chips)
                              if flops_dev else 0.0),
            ),
        )
    except Exception as e:  # noqa: BLE001 -- a failing cell is a bug report
        out.update(status="error", error=f"{type(e).__name__}: {e}",
                   trace=traceback.format_exc()[-2000:])
    if verbose:
        st = out["status"]
        if st == "ok":
            r = out["roofline"]
            print(f"[{mesh_tag}] {arch:22s} {shape_name:12s} OK "
                  f"compile={out['compile_s']:.0f}s "
                  f"peak={out['memory']['peak_estimate_bytes']/2**30:.2f}GiB "
                  f"dom={r['dominant']:12s} useful={r['useful_ratio']:.2f}",
                  flush=True)
        else:
            print(f"[{mesh_tag}] {arch:22s} {shape_name:12s} {st}: "
                  f"{out.get('reason', out.get('error'))}", flush=True)
    _save(out, save_dir)
    return out


def run_ea_cell(multi_pod: bool, save_dir: Optional[str],
                verbose: bool = True) -> Dict[str, Any]:
    """The paper's own workload on the production mesh: one NSGA-II island
    round (evolve + ring migration) per device over the whole pod --
    256 (single-pod) / 512 (multi-pod) islands of the VU11P placement."""
    from repro.core import evolve, nsga2
    from repro.fpga import device as fdev, netlist

    mesh_tag = "pod2x16x16" if multi_pod else "pod16x16"
    out: Dict[str, Any] = {"arch": "vu_systolic", "shape": "ea_round",
                           "mesh": mesh_tag, "params_b": 0}
    mesh = make_production_mesh(multi_pod=multi_pod)
    axes = tuple(mesh.axis_names)
    # xcvu_test keeps the placeholder-device execution tractable on one CPU
    # simulating 256/512 chips; the mesh/collective structure is identical
    # to the VU11P production run (same shard_map, same ring migration)
    prob = netlist.make_problem(fdev.get_device("xcvu_test"))
    t0 = time.time()
    try:
        # the EA is cheap enough to EXECUTE on the placeholder devices --
        # one island per chip across the whole pod, ring migration live
        st, hist = evolve.run_islands(
            prob, "nsga2", nsga2.NSGA2Config(pop_size=16),
            jax.random.PRNGKey(0), rounds=1, gens_per_round=2,
            mesh=mesh, axis=axes)
        jax.block_until_ready(hist)
        out.update(status="ok", compile_s=round(time.time() - t0, 2),
                   lower_s=0.0, n_micro=1,
                   memory={"argument_bytes": 0, "output_bytes": 0,
                           "temp_bytes": 0, "alias_bytes": 0,
                           "peak_estimate_bytes": 0},
                   cost={}, collectives={"total": 0.0},
                   roofline={"note": "EA islands execute (not just lower) "
                             "on the placeholder mesh", "dominant": "n/a"},
                   best_objs=[float(x) for x in
                              __import__("numpy").asarray(hist)[-1].min(0)])
    except Exception as e:  # noqa: BLE001
        out.update(status="error", error=f"{type(e).__name__}: {e}",
                   trace=traceback.format_exc()[-2000:])
    if verbose:
        print(f"[{mesh_tag}] vu_systolic            ea_round     "
              f"{out['status']} ({out.get('compile_s', 0)}s, "
              f"{mesh.devices.size} islands)", flush=True)
    _save(out, save_dir)
    return out


def _save(out: Dict[str, Any], save_dir: Optional[str]):
    if not save_dir:
        return
    os.makedirs(save_dir, exist_ok=True)
    path = os.path.join(
        save_dir, f"{out['arch']}__{out['shape']}__{out['mesh']}.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--rules", default=None,
                    help="JSON logical-rule overrides, e.g. "
                         "'{\"kv_seq\": [\"data\",\"model\"]}'")
    args = ap.parse_args()

    overrides = None
    if args.rules:
        overrides = {
            k: (tuple(v) if isinstance(v, list) else v)
            for k, v in json.loads(args.rules).items()}

    meshes = [args.multi_pod]
    if args.both_meshes:
        meshes = [False, True]

    cells = []
    if args.all:
        for arch in cbase.ARCHS:
            for shape in SHAPES:
                cells.append((arch, shape))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells.append((args.arch, args.shape))

    n_bad = 0
    for mp in meshes:
        for arch, shape in cells:
            res = run_cell(arch, shape, mp, overrides, args.out)
            if res["status"] == "error":
                n_bad += 1
    if n_bad:
        raise SystemExit(f"{n_bad} dry-run cells failed")


if __name__ == "__main__":
    main()
