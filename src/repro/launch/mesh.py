"""Production mesh construction (single-pod 16x16 / multi-pod 2x16x16).

A FUNCTION, not a module-level constant: importing this module never
touches jax device state (device count locks on first backend init, and
only launch/dryrun.py is allowed to force the 512-device host platform).
"""
from __future__ import annotations

import jax

from repro.runtime.jaxcompat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_local_mesh(model_parallel: int = 1):
    """Whatever this host has, split (data, model) -- tests/examples."""
    n = jax.device_count()
    assert n % model_parallel == 0
    return make_mesh((n // model_parallel, model_parallel),
                     ("data", "model"))
