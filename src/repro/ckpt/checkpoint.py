"""Fault-tolerant checkpointing: atomic, async, elastic-reshard on restore.

Layout per step:  <dir>/step_<n>/ {manifest.json, arrays.npz}
Write protocol:   tmp dir -> fsync -> atomic rename (a crashed save can never
shadow a good checkpoint); `keep` newest are retained; saves can run on a
background thread (async) so the training loop never blocks on disk.

Restore takes target `shardings`: arrays are `device_put` straight onto the
*current* mesh regardless of the mesh at save time -- that is the elastic
path (N hosts -> M hosts just changes the shardings you pass).  Multi-host
deployments would write per-shard files keyed by a global index; this
single-controller implementation keeps the same manifest contract.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any, Dict, List, Optional

import jax
import numpy as np

_EXEC = ThreadPoolExecutor(max_workers=1, thread_name_prefix="ckpt")
_LOCK = threading.Lock()


def _flatten(tree: Any) -> Dict[str, np.ndarray]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return {jax.tree_util.keystr(path): np.asarray(leaf)
            for path, leaf in flat}


def save(directory: str, step: int, tree: Any,
         meta: Optional[Dict[str, Any]] = None, keep: int = 3,
         async_: bool = False) -> Optional[Future]:
    """Checkpoint `tree` at `step`.  Returns a Future when async_."""
    arrays = _flatten(tree)      # host transfer happens on the caller thread

    def _write():
        with _LOCK:
            final = os.path.join(directory, f"step_{step:08d}")
            tmp = final + ".tmp"
            os.makedirs(tmp, exist_ok=True)
            np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
            manifest = {"step": step, "meta": meta or {},
                        "n_arrays": len(arrays)}
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
                f.flush()
                os.fsync(f.fileno())
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)
            _gc(directory, keep)
        return final

    if async_:
        return _EXEC.submit(_write)
    _write()
    return None


def _gc(directory: str, keep: int) -> None:
    steps = sorted(latest_steps(directory))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(directory, f"step_{s:08d}"),
                      ignore_errors=True)


def latest_steps(directory: str) -> List[int]:
    if not os.path.isdir(directory):
        return []
    out = []
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(directory, name, "manifest.json")):
                out.append(int(name.split("_")[1]))
    return sorted(out)


def restore(directory: str, like: Any, step: Optional[int] = None,
            shardings: Any = None) -> Any:
    """Restore into the structure of `like`.

    `shardings` (same tree structure, NamedSharding leaves) places each
    array onto the current mesh -- restoring onto a different mesh than the
    one that saved is the supported elastic path.
    """
    steps = latest_steps(directory)
    if not steps:
        raise FileNotFoundError(f"no checkpoints under {directory}")
    step = step if step is not None else steps[-1]
    path = os.path.join(directory, f"step_{step:08d}")
    with np.load(os.path.join(path, "arrays.npz")) as z:
        arrays = {k: z[k] for k in z.files}
    flat = jax.tree_util.tree_flatten_with_path(like)[0]
    treedef = jax.tree.structure(like)
    shard_leaves = (jax.tree.leaves(shardings)
                    if shardings is not None else [None] * len(flat))
    leaves = []
    for (pathk, leaf), shd in zip(flat, shard_leaves):
        key = jax.tree_util.keystr(pathk)
        a = arrays[key].astype(leaf.dtype)
        assert a.shape == leaf.shape, (key, a.shape, leaf.shape)
        leaves.append(jax.device_put(a, shd) if shd is not None
                      else jax.numpy.asarray(a))
    return jax.tree.unflatten(treedef, leaves)


def manifest(directory: str, step: Optional[int] = None) -> Dict[str, Any]:
    steps = latest_steps(directory)
    step = step if step is not None else steps[-1]
    with open(os.path.join(directory, f"step_{step:08d}",
                           "manifest.json")) as f:
        return json.load(f)
