"""Async front-end + unified serve API: admission, cancellation, drain.

Covers the PR's acceptance contracts:
  * one request type: `serve.api.JobRequest` accepted by both
    `PlacementService.submit` and `PlacementScheduler.submit`; the legacy
    kwarg forms emit `DeprecationWarning` and produce bitwise-identical
    results,
  * one handle type: `JobHandle.status` / `.result()` / `.exception()`
    with the PR 1-8 attributes (`.done`, `.failed`) as deprecated
    properties,
  * versioned stats: every layer's `stats()` carries `schema_version`
    and the documented typed keys,
  * the front-end: cancellation actually frees and reuses the slot,
    bounded admission blocks (`await submit`) / raises (`submit_nowait`)
    under load and drains as jobs finish, `drain()` loses and duplicates
    nothing, and concurrent submission is bitwise deterministic against
    a hand-pumped sequential scheduler.

No pytest-asyncio in the toolchain: async scenarios run under
`asyncio.run()` inside synchronous tests.
"""
import asyncio

import numpy as np
import pytest

from repro.core import nsga2
from repro.fpga import device, netlist
from repro.serve.api import (JobCancelledError, JobHandle, JobRequest,
                             JobStatus, QueueFull)
from repro.serve.frontend import PlacementFrontend
from repro.serve.placement_service import PlacementService
from repro.serve.scheduler import PlacementScheduler

BASE = netlist.make_problem(device.get_device("xcvu_test"))
CFG = nsga2.NSGA2Config(pop_size=8)


def _req(seed: int, budget: int = 4, **kw) -> JobRequest:
    return JobRequest(device="xcvu_test", cfg=CFG, seed=seed,
                      budget=budget, **kw)


def _drain_service(svc) -> dict:
    done = {}
    while svc.active.any():
        for j in svc.step():
            done[j.jid] = j
    return done


# ------------------------------------------------- unified request type

def test_service_kwargs_vs_request_bitwise_identical():
    svc_kw = PlacementService(BASE, CFG, n_slots=1, gens_per_step=2)
    with pytest.warns(DeprecationWarning, match="JobRequest"):
        jid_kw = svc_kw.submit(cfg=CFG, seed=7, budget=4)
    svc_rq = PlacementService(BASE, CFG, n_slots=1, gens_per_step=2)
    jid_rq = svc_rq.submit_request(JobRequest(cfg=CFG, seed=7, budget=4))
    a = _drain_service(svc_kw)[jid_kw]
    b = _drain_service(svc_rq)[jid_rq]
    assert np.array_equal(a.best_objs, b.best_objs)
    assert a.metric == b.metric
    for t in a.genotype:
        for x, y in zip(a.genotype[t], b.genotype[t]):
            assert np.array_equal(x, y)


def test_scheduler_kwargs_vs_request_bitwise_identical():
    s_kw = PlacementScheduler(n_slots=1, gens_per_step=2)
    with pytest.warns(DeprecationWarning, match="JobRequest"):
        jid_kw = s_kw.submit("xcvu_test", CFG, seed=9, budget=4)
    s_rq = PlacementScheduler(n_slots=1, gens_per_step=2)
    jid_rq = s_rq.submit_request(_req(seed=9))
    a = {j.jid: j for j in s_kw.run_all()}[jid_kw].result
    b = {j.jid: j for j in s_rq.run_all()}[jid_rq].result
    assert np.array_equal(a.best_objs, b.best_objs)
    assert a.metric == b.metric


def test_request_validation_rejects_mismatched_routing():
    svc = PlacementService(BASE, CFG, n_slots=1, gens_per_step=2)
    with pytest.raises(ValueError, match="algo"):
        svc.submit_request(JobRequest(cfg=CFG, algo="cmaes", seed=0))
    with pytest.raises(ValueError, match="gens_per_step"):
        svc.submit_request(JobRequest(cfg=CFG, seed=0, gens_per_step=7))
    sched = PlacementScheduler(n_slots=1)
    with pytest.raises(ValueError, match="device"):
        sched.submit_request(JobRequest(cfg=CFG, seed=0))
    with pytest.raises(ValueError, match="cfg"):
        sched.submit_request(JobRequest(device="xcvu_test", seed=0))


# --------------------------------------------------- unified handle type

def test_jobhandle_deprecated_attributes_still_work():
    h = JobHandle(jid=0, request=_req(seed=0))
    with pytest.warns(DeprecationWarning, match="status"):
        assert h.done is False
    with pytest.warns(DeprecationWarning, match="status"):
        assert h.failed is False
    h._resolve("payload")
    with pytest.warns(DeprecationWarning):
        assert h.done is True
    assert h.status is JobStatus.DONE
    assert h.result(timeout=0) == "payload"
    assert h.exception(timeout=0) is None
    assert h.cancel() is False            # terminal: too late


def test_jobhandle_failure_and_timeout_surface():
    h = JobHandle(jid=1, request=_req(seed=1))
    with pytest.raises(TimeoutError):
        h.result(timeout=0.01)
    h._fail(RuntimeError("boom"))
    assert h.status is JobStatus.FAILED
    with pytest.raises(RuntimeError, match="boom"):
        h.result(timeout=0)
    assert isinstance(h.exception(timeout=0), RuntimeError)


# --------------------------------------------------- versioned stats

def test_stats_schema_versioned_across_layers():
    svc = PlacementService(BASE, CFG, n_slots=1, gens_per_step=2)
    s = svc.stats()
    assert s["schema_version"] == 2
    for key in ("n_slots", "steps", "step_compiles", "jobs_cancelled",
                "time_to_first_gen_ms", "recompiles_total",
                "step_ms_hist", "convergence", "tracing_enabled"):
        assert key in s
    sched = PlacementScheduler(n_slots=1, gens_per_step=2)
    sched.submit_request(_req(seed=3))
    sched.run_all()
    f = sched.stats()
    assert f["schema_version"] == 2
    assert f["jobs_done"] == 1 and f["jobs_cancelled"] == 0
    assert "job_latency_ms_hist" in f
    assert all(p["schema_version"] == 2 for p in f["pools"].values())


# --------------------------------------------------------- cancellation

def test_service_cancel_frees_and_reuses_slot():
    svc = PlacementService(BASE, CFG, n_slots=2, gens_per_step=2)
    a = svc.submit_request(JobRequest(cfg=CFG, seed=1, budget=8))
    b = svc.submit_request(JobRequest(cfg=CFG, seed=2, budget=8))
    assert svc.submit_request(JobRequest(cfg=CFG, seed=3)) is None  # full
    assert svc.cancel(a) is True
    assert svc.cancel(a) is False          # already freed
    c = svc.submit_request(JobRequest(cfg=CFG, seed=3, budget=4))
    assert c is not None                   # the freed slot, reused
    done = _drain_service(svc)
    assert set(done) == {b, c}             # cancelled job never harvested
    assert svc.stats()["jobs_cancelled"] == 1


def test_scheduler_cancel_pending_and_inflight():
    sched = PlacementScheduler(n_slots=1, gens_per_step=2)
    running = sched.submit_request(_req(seed=1, budget=8))
    queued = sched.submit_request(_req(seed=2, budget=4))
    waiting = sched.submit_request(_req(seed=3, budget=4))
    assert sched.jobs[running].status is JobStatus.RUNNING
    assert sched.jobs[queued].status is JobStatus.QUEUED
    assert sched.cancel(queued) is True    # leaves the FIFO
    assert sched.cancel(running) is True   # frees + refills the slot
    assert sched.jobs[waiting].status is JobStatus.RUNNING
    done = {j.jid for j in sched.run_all()}
    assert done == {waiting}
    assert sched.cancel(waiting) is False  # terminal: too late
    s = sched.stats()
    assert s["jobs_cancelled"] == 2 and s["jobs_done"] == 1


def test_frontend_cancel_frees_slot_at_step_boundary():
    async def main():
        sched = PlacementScheduler(n_slots=1, gens_per_step=2)
        async with PlacementFrontend(sched, max_queue=4) as fe:
            big = await fe.submit(_req(seed=1, budget=10_000))
            small = await fe.submit(_req(seed=2, budget=4))
            # wait until the long job is actually occupying the slot
            async for _ in big.progress():
                break
            assert big.cancel() is True
            with pytest.raises(JobCancelledError):
                await big.wait()
            assert big.status is JobStatus.CANCELLED
            r = await small.wait()         # ran in the freed slot
            assert r.done and r.gens == 4
            return fe.stats()
    s = asyncio.run(main())
    assert s["cancelled"] == 1 and s["completed"] == 1
    assert s["fleet"]["jobs_cancelled"] == 1


# --------------------------------------------------------- backpressure

def test_backpressure_blocks_then_drains():
    async def main():
        sched = PlacementScheduler(n_slots=2, gens_per_step=2)
        async with PlacementFrontend(sched, max_queue=2) as fe:
            h1 = fe.submit_nowait(_req(seed=1, budget=10_000))
            h2 = fe.submit_nowait(_req(seed=2, budget=10_000))
            with pytest.raises(QueueFull):
                fe.submit_nowait(_req(seed=3))
            assert fe.queue_full_rejections == 1
            blocked = asyncio.create_task(fe.submit(_req(seed=4, budget=4)))
            await asyncio.sleep(0.05)
            assert not blocked.done()      # caller suspended, not erroring
            assert fe.backpressure_waits == 1
            assert h1.cancel() is True     # frees one admission credit
            h4 = await blocked             # ...which un-blocks the submit
            r = await h4.wait()
            assert r.done
            h2.cancel()
            with pytest.raises(JobCancelledError):
                await h2.wait()
    asyncio.run(main())


# ------------------------------------------------------ drain under load

def test_drain_under_load_loses_and_duplicates_nothing():
    seeds = list(range(20, 28))

    async def main():
        sched = PlacementScheduler(n_slots=2, gens_per_step=2)
        fe = PlacementFrontend(sched, max_queue=len(seeds))
        async with fe:
            handles = [await fe.submit(_req(seed=s, budget=4))
                       for s in seeds]
            await fe.drain()
            with pytest.raises(RuntimeError, match="draining"):
                await fe.submit(_req(seed=99))
            assert all(h.status is JobStatus.DONE for h in handles)
            results = [h.result(timeout=0) for h in handles]
            # nothing lost, nothing duplicated: every submit produced
            # exactly one distinct finished job
            assert len({id(r) for r in results}) == len(seeds)
            assert all(r.done and r.gens == 4 for r in results)
            s = fe.stats()
            assert s["submitted"] == s["completed"] == len(seeds)
            assert s["failed"] == 0 and s["cancelled"] == 0
            assert s["fleet"]["jobs_done"] == len(seeds)
    asyncio.run(main())


# --------------------------------------- concurrent-submit determinism

def test_concurrent_submit_matches_sequential_bitwise():
    reqs = [_req(seed=100 + i, budget=6) for i in range(5)]

    sched = PlacementScheduler(n_slots=2, gens_per_step=2)
    jids = [sched.submit_request(r) for r in reqs]
    by_jid = {j.jid: j for j in sched.run_all()}
    ref = {r.seed: by_jid[j].result.best_objs for r, j in zip(reqs, jids)}

    async def main():
        sched2 = PlacementScheduler(n_slots=2, gens_per_step=2)
        async with PlacementFrontend(sched2, max_queue=8) as fe:
            handles = await asyncio.gather(*[fe.submit(r) for r in reqs])
            out = await asyncio.gather(*[h.wait() for h in handles])
        return {r.seed: pj.best_objs for r, pj in zip(reqs, out)}

    got = asyncio.run(main())
    for r in reqs:
        assert np.array_equal(ref[r.seed], got[r.seed])


# ----------------------------------------------------- progress stream

def test_progress_stream_monotone_and_terminates():
    async def main():
        sched = PlacementScheduler(n_slots=1, gens_per_step=2)
        async with PlacementFrontend(sched, max_queue=2) as fe:
            h = await fe.submit(_req(seed=5, budget=12))
            gens = []
            async for u in h.progress():
                assert u.status is JobStatus.RUNNING
                assert np.isfinite(u.metric)
                gens.append(u.gens)
            assert gens == sorted(gens)    # monotone generation counter
            assert gens and gens[-1] <= 12
            assert h.status is JobStatus.DONE
            r = await h.wait()
            assert r.gens == 12
    asyncio.run(main())


def test_frontend_bad_request_fails_handle_not_thread():
    async def main():
        sched = PlacementScheduler(n_slots=1, gens_per_step=2)
        async with PlacementFrontend(sched, max_queue=4) as fe:
            bad = await fe.submit(JobRequest(cfg=CFG, seed=0))  # no device
            with pytest.raises(ValueError, match="device"):
                await bad.wait()
            assert bad.status is JobStatus.FAILED
            good = await fe.submit(_req(seed=6, budget=4))
            r = await good.wait()          # co-tenants keep flowing
            assert r.done
            s = fe.stats()
            assert s["failed"] == 1 and s["completed"] == 1
    asyncio.run(main())
