"""Dry-run smoke: one real (arch x shape x mesh) cell compiles in a fresh
subprocess with the 512-device host platform (the flag must not leak into
this test process), and the multi-device island runner works under a forced
8-device CPU topology."""
import json
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str, flags: str) -> subprocess.CompletedProcess:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env["XLA_FLAGS"] = flags
    return subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=560,
                          cwd=ROOT)


@pytest.mark.slow
def test_one_dryrun_cell_compiles(tmp_path):
    code = (
        "from repro.launch.dryrun import run_cell\n"
        f"out = run_cell('musicgen-large', 'decode_32k', False, "
        f"save_dir=r'{tmp_path}')\n"
        "assert out['status'] == 'ok', out\n"
    )
    # the dryrun module sets its own XLA_FLAGS on import (first lines)
    p = _run(code, "")
    assert p.returncode == 0, p.stderr[-2000:]
    fname = tmp_path / "musicgen-large__decode_32k__pod16x16.json"
    d = json.loads(fname.read_text())
    assert d["roofline"]["dominant"] in ("compute_s", "memory_s",
                                         "collective_s")
    assert d["collectives"]["total"] > 0      # seq-sharded decode psums


@pytest.mark.slow
def test_islands_on_eight_devices():
    code = (
        "import jax\n"
        "assert jax.device_count() == 8, jax.device_count()\n"
        "from repro.core import evolve, nsga2, objectives as O\n"
        "from repro.fpga import device, netlist\n"
        "import numpy as np\n"
        "prob = netlist.make_problem(device.get_device('xcvu_test'))\n"
        "st, hist = evolve.run_islands(prob, 'nsga2',\n"
        "    nsga2.NSGA2Config(pop_size=8), jax.random.PRNGKey(0),\n"
        "    rounds=2, gens_per_round=3)\n"
        "assert hist.shape[1] == 8\n"
        "c = np.asarray(O.combined_metric(hist))\n"
        "assert np.isfinite(c).all()\n"
        "print('islands ok', c[-1].min())\n"
    )
    p = _run(code, "--xla_force_host_platform_device_count=8")
    assert p.returncode == 0, p.stderr[-2000:]
    assert "islands ok" in p.stdout
