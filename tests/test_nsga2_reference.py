"""NSGA-II sorting machinery vs brute-force O(P^2 M) numpy references.

Checks `nondominated_rank` and `crowding_distance` against direct
definitional implementations on randomized objective sets, including
heavy ties (quantized objectives) and exactly duplicated points -- the
cases where scatter/segment tricks in the vectorized versions can slip.
"""
import numpy as np
import pytest

from repro.core import nsga2

INF = 1e9


def rank_reference(objs: np.ndarray) -> np.ndarray:
    """Peel-off non-dominated sorting straight from the definition."""
    p = objs.shape[0]
    rank = np.full(p, -1)
    alive = np.ones(p, bool)
    r = 0
    while alive.any():
        front = []
        for i in np.where(alive)[0]:
            dominated = False
            for j in np.where(alive)[0]:
                if i != j and np.all(objs[j] <= objs[i]) \
                        and np.any(objs[j] < objs[i]):
                    dominated = True
                    break
            if not dominated:
                front.append(i)
        for i in front:
            rank[i] = r
            alive[i] = False
        r += 1
    return rank


def crowding_reference(objs: np.ndarray, rank: np.ndarray) -> np.ndarray:
    """Per-front crowding distance from the definition (Deb et al. 2002).

    Matches the vectorized implementation's conventions: stable sort by
    (value, original index) within each front, per-front range clipped at
    1e-12, boundary points get one INF (1e9) *per objective*.
    """
    p, m = objs.shape
    crowd = np.zeros(p)
    for r in np.unique(rank):
        idx = np.where(rank == r)[0]
        for mm in range(m):
            f = objs[idx, mm].astype(np.float64)
            order = idx[np.argsort(f, kind="stable")]
            fs = objs[order, mm].astype(np.float64)
            rng = max(fs[-1] - fs[0], 1e-12)
            for k, i in enumerate(order):
                if k == 0 or k == len(order) - 1:
                    crowd[i] += INF
                else:
                    crowd[i] += (fs[k + 1] - fs[k - 1]) / rng
    return crowd


def _check(objs: np.ndarray) -> None:
    got_rank = np.asarray(nsga2.nondominated_rank(objs.astype(np.float32)))
    want_rank = rank_reference(objs)
    np.testing.assert_array_equal(got_rank, want_rank)
    got_crowd = np.asarray(nsga2.crowding_distance(
        objs.astype(np.float32), got_rank))
    want_crowd = crowding_reference(objs.astype(np.float32), want_rank)
    np.testing.assert_allclose(got_crowd, want_crowd, rtol=1e-4, atol=1e-6)


@pytest.mark.parametrize("seed", range(8))
@pytest.mark.parametrize("m", [2, 3])
def test_random_objectives_match_reference(seed, m):
    rng = np.random.default_rng(seed)
    p = int(rng.integers(3, 48))
    _check(rng.uniform(size=(p, m)))


@pytest.mark.parametrize("seed", range(6))
def test_tied_objectives_match_reference(seed):
    # coarse quantization -> many exact per-objective ties across fronts
    rng = np.random.default_rng(100 + seed)
    p = int(rng.integers(4, 40))
    objs = np.round(rng.uniform(size=(p, 2)) * 4.0) / 4.0
    _check(objs)


@pytest.mark.parametrize("seed", range(4))
def test_duplicated_points_match_reference(seed):
    # exact duplicates: mutually non-dominating, land in the same front
    rng = np.random.default_rng(200 + seed)
    base = rng.uniform(size=(6, 2))
    dup = base[rng.integers(0, 6, size=5)]
    _check(np.concatenate([base, dup]))


def test_single_point_and_single_front():
    _check(np.array([[0.3, 0.7]]))
    # one big mutually non-dominated front
    t = np.linspace(0.0, 1.0, 9)
    _check(np.stack([t, 1.0 - t], axis=1))


def test_chain_of_fronts():
    # strictly dominated chain: one point per front
    t = np.arange(5, dtype=np.float64)
    objs = np.stack([t, t], axis=1)
    rank = np.asarray(nsga2.nondominated_rank(objs.astype(np.float32)))
    np.testing.assert_array_equal(rank, np.arange(5))
    crowd = np.asarray(nsga2.crowding_distance(
        objs.astype(np.float32), rank))
    assert (crowd >= 2 * INF - 1).all()   # singleton fronts: INF per objective
