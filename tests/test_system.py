"""End-to-end system tests: trainer + checkpoint/restart + failure recovery,
data determinism, optimizer behaviour, serving engine round trips."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.ckpt import checkpoint
from repro.configs import get_reduced
from repro.data.pipeline import DataConfig, Pipeline
from repro.models import transformer as T
from repro.serve.engine import Engine
from repro.train import optimizer as opt
from repro.train.trainer import Trainer, TrainerConfig

KEY = jax.random.PRNGKey(0)


def _trainer(tmp, steps=6, arch="yi-6b", inject=None, ckpt_every=2,
             total_steps=None):
    red = get_reduced(arch)
    dcfg = DataConfig(vocab=red.vocab, seq_len=32, global_batch=4)
    return Trainer(
        red, opt.OptConfig(lr=1e-3, warmup_steps=2,
                           total_steps=total_steps or steps),
        TrainerConfig(steps=steps, ckpt_every=ckpt_every,
                      ckpt_dir=os.path.join(tmp, "ckpt"), log_every=1,
                      inject_failure_at=inject),
        dcfg)


def test_training_reduces_loss(tmp_path):
    # the reduced model starts at ~ln(V) on the noisy 2-gram stream and
    # needs ~50+ steps before the learning trend clears per-step noise
    # (~0.02 nats); 100 steps gives a ~0.05-nat first/last margin
    tr = _trainer(str(tmp_path), steps=100)
    hist = tr.run()
    first = np.mean([h["loss"] for h in hist[:5]])
    last = np.mean([h["loss"] for h in hist[-5:]])
    assert last < first, (first, last)


def test_checkpoint_restart_bitwise(tmp_path):
    """Stop at 4, restart, continue to 8 == uninterrupted run to 8."""
    t1 = _trainer(str(tmp_path / "a"), steps=8, ckpt_every=4)
    h_full = t1.run()
    t2 = _trainer(str(tmp_path / "b"), steps=4, ckpt_every=4,
                  total_steps=8)    # same LR schedule as the full run
    t2.run()
    t3 = _trainer(str(tmp_path / "b"), steps=8, ckpt_every=4)
    assert t3.step == 4          # restored
    h_resumed = t3.run()
    a = jax.tree.leaves(t1.params)
    b = jax.tree.leaves(t3.params)
    for x, y in zip(a, b):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=1e-6, atol=1e-7)


def test_failure_recovery_resumes(tmp_path):
    tr = _trainer(str(tmp_path), steps=8, inject=5, ckpt_every=2)
    hist = tr.run_with_recovery()
    assert tr.step == 8
    assert np.isfinite(hist[-1]["loss"])


def test_checkpoint_atomicity(tmp_path):
    d = str(tmp_path / "ck")
    tree = {"a": jnp.arange(10.0), "b": {"c": jnp.ones((3, 3))}}
    checkpoint.save(d, 1, tree)
    checkpoint.save(d, 2, jax.tree.map(lambda x: x * 2, tree))
    assert checkpoint.latest_steps(d) == [1, 2]
    got = checkpoint.restore(d, tree, step=2)
    np.testing.assert_allclose(np.asarray(got["a"]),
                               np.arange(10.0) * 2)
    # keep=1 garbage-collects older steps
    checkpoint.save(d, 3, tree, keep=1)
    assert checkpoint.latest_steps(d) == [3]


def test_checkpoint_async(tmp_path):
    d = str(tmp_path / "ck")
    fut = checkpoint.save(d, 7, {"x": jnp.ones(4)}, async_=True)
    fut.result(timeout=30)
    assert checkpoint.latest_steps(d) == [7]


# --------------------------------------------------------------- data

@settings(max_examples=10, deadline=None)
@given(step=st.integers(0, 1000), seed=st.integers(0, 99))
def test_pipeline_deterministic(step, seed):
    cfg = DataConfig(vocab=101, seq_len=16, global_batch=8, seed=seed)
    b1 = Pipeline(cfg).batch(step)
    b2 = Pipeline(cfg).batch(step)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])


def test_pipeline_shards_partition_global_batch():
    cfg = DataConfig(vocab=101, seq_len=16, global_batch=8, seed=3)
    full = Pipeline(cfg, 0, 1).batch(5)
    parts = [Pipeline(cfg, i, 4).batch(5) for i in range(4)]
    # shards must be disjoint deterministic streams; same shapes
    for p in parts:
        assert p["tokens"].shape == (2, 16)
    assert len({p["tokens"].tobytes() for p in parts}) == 4
    assert full["tokens"].shape == (8, 16)


def test_pipeline_targets_are_next_token():
    cfg = DataConfig(vocab=101, seq_len=16, global_batch=2, seed=0)
    b = Pipeline(cfg).batch(0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["targets"][:, :-1])


# ----------------------------------------------------------- optimizer

def test_adamw_descends_quadratic():
    p = {"w": jnp.ones(8) * 5.0}
    st_ = opt.init(p)
    cfg = opt.OptConfig(lr=0.1, warmup_steps=1, total_steps=100,
                        weight_decay=0.0, schedule="const")
    for _ in range(150):
        g = {"w": 2 * st_["master"]["w"]}
        p, st_, _ = opt.update(cfg, p, g, st_)
    assert float(jnp.abs(p["w"]).max()) < 0.3


def test_grad_clip_bounds_update():
    p = {"w": jnp.ones(4)}
    st_ = opt.init(p)
    cfg = opt.OptConfig(lr=1.0, clip_norm=1e-3, warmup_steps=1,
                        schedule="const", weight_decay=0.0)
    g = {"w": jnp.full(4, 1e6)}
    _, _, m = opt.update(cfg, p, g, st_)
    assert float(m["grad_norm"]) > 1e5   # raw norm reported


def test_int8_compression_error_feedback_unbiased():
    rng = np.random.default_rng(0)
    g_true = jnp.asarray(rng.normal(0, 1, (64,)), jnp.float32)
    err = jnp.zeros(64)
    acc = jnp.zeros(64)
    for _ in range(200):
        deq, err = opt.compress_with_feedback({"g": g_true}, {"g": err})[0][
            "g"], opt.compress_with_feedback({"g": g_true}, {"g": err})[1]["g"]
        acc = acc + deq
    # time-average converges to the true gradient (error feedback)
    np.testing.assert_allclose(np.asarray(acc / 200), np.asarray(g_true),
                               atol=0.05)


def test_schedules_shape():
    cfg = opt.OptConfig(lr=1.0, warmup_steps=10, total_steps=100)
    lrs = [float(opt.schedule_lr(cfg, jnp.asarray(s)))
           for s in [0, 5, 10, 50, 100]]
    assert lrs[0] == 0.0 and lrs[1] == pytest.approx(0.5)
    assert lrs[2] == pytest.approx(1.0) and lrs[-1] < 0.01
    wsd = opt.OptConfig(lr=1.0, warmup_steps=0, total_steps=100,
                        schedule="wsd")
    assert float(opt.schedule_lr(wsd, jnp.asarray(50))) == pytest.approx(1.0)


# ------------------------------------------------------------- serving

def test_engine_generates_and_frees_slots():
    red = get_reduced("yi-6b")
    params = T.init_params(red, KEY, jnp.float32)
    eng = Engine(red, params, n_slots=2, max_len=48, eos_id=-1)
    prompts = [np.arange(4) % red.vocab, np.arange(6) % red.vocab,
               np.arange(5) % red.vocab]
    out = eng.generate(prompts, max_new=6)
    assert set(out) == {0, 1, 2}
    assert all(len(v) == 6 for v in out.values())
    assert not eng.active.any()


def test_engine_matches_offline_greedy():
    """Engine greedy decode == manual prefill+decode loop."""
    red = get_reduced("granite-8b")
    params = T.init_params(red, KEY, jnp.float32)
    prompt = np.asarray([3, 5, 7, 11], np.int32)
    eng = Engine(red, params, n_slots=1, max_len=32, eos_id=-1)
    out = eng.generate([prompt], max_new=5)[0]

    logits, caches, clen = T.prefill(params, red, jnp.asarray(prompt)[None],
                                     32)
    toks = [int(jnp.argmax(logits[0]))]
    for _ in range(4):
        nxt, caches = T.decode_step(
            params, red, jnp.asarray([toks[-1]], jnp.int32), caches, clen)
        clen = clen + 1
        toks.append(int(jnp.argmax(nxt[0])))
    assert out == toks
