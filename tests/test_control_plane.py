"""Placement control plane: signatures, champion cache, policies, scaling.

Covers the PR's acceptance contracts:
  * `Problem`/`DeviceModel` content signatures: stable across rebuilds,
    exact for identical geometry, sibling keys matching across the
    `xcvu_test`/`xcvu_test2` pair, `transfer.auto_migrate` identity,
  * the champion store: an exact-signature hit meeting `target` serves a
    finished job without touching a pool, a sibling hit warm-starts it
    (and beats a cold run to the same target), write-back only on strict
    improvement, JSON persistence round-trips, and with no store the
    scheduler's results are bitwise identical to a standalone service,
  * stepping policies: round-robin cannot starve a pool behind a busy
    neighbour, deadline = earliest-deadline-first, priority = highest
    first, and policies change completion order, never results,
  * autoscaling: queue depth grows a pool along the geometric slot
    ladder, live jobs carry over, compiles stay O(#sizes), and per-job
    results match a never-grown pool.
"""
import json

import jax
import numpy as np
import pytest

from repro.core import nsga2, transfer
from repro.core import genotype as G
from repro.core import objectives as O
from repro.fpga import device, netlist
from repro.serve.champion_store import ChampionStore
from repro.serve.placement_service import PlacementService
from repro.serve.policy import (DeadlinePolicy, PoolView, PriorityPolicy,
                                RoundRobinPolicy, get_policy)
from repro.serve.scheduler import PlacementScheduler

KEY = jax.random.PRNGKey(0)
BASE = netlist.make_problem(device.get_device("xcvu_test"))
SIB = netlist.make_problem(device.get_device("xcvu_test2"))


@pytest.fixture(scope="module")
def base_champion():
    """A converged xcvu_test champion (shared: the convergence run
    dominates this module's cost)."""
    g = transfer.converge_champion(BASE, KEY, 32, 80)
    return jax.tree.map(np.asarray, g)


def _metric(problem, g) -> float:
    return float(O.combined_metric(O.evaluate(problem, g)))


# ------------------------------------------------------------- signatures

def test_problem_signature_stable_and_content_keyed():
    again = netlist.make_problem(device.get_device("xcvu_test"))
    assert BASE.signature == again.signature
    assert BASE.sibling_key == again.sibling_key
    assert BASE.signature != SIB.signature          # different column xs
    assert BASE.sibling_key == SIB.sibling_key      # same structure
    vu3p = netlist.make_problem(device.get_device("xcvu3p"))
    assert BASE.signature != vu3p.signature
    assert BASE.sibling_key != vu3p.sibling_key     # different shape


def test_device_signature_matches_problem_granularity():
    d1, d2 = device.get_device("xcvu_test"), device.get_device("xcvu_test2")
    assert d1.signature == device.get_device("xcvu_test").signature
    assert d1.signature != d2.signature
    assert d1.sibling_key == d2.sibling_key


def test_auto_migrate_identity_on_same_signature():
    g = G.random_genotype(KEY, BASE)
    same = transfer.auto_migrate(BASE, BASE, g)
    assert same is g                                 # no projection work
    projected = transfer.auto_migrate(BASE, SIB, g)
    O.assert_valid(SIB, projected)


# --------------------------------------------------------- champion store

def test_store_write_back_only_on_improvement(base_champion):
    store = ChampionStore()
    g_bad = G.random_genotype(KEY, BASE)
    assert store.put(BASE, g_bad, _metric(BASE, g_bad),
                     np.asarray(O.evaluate(BASE, g_bad)))
    assert store.put(BASE, base_champion, _metric(BASE, base_champion),
                     np.asarray(O.evaluate(BASE, base_champion)))
    # a worse genotype must NOT replace the champion
    assert not store.put(BASE, g_bad, _metric(BASE, g_bad),
                         np.asarray(O.evaluate(BASE, g_bad)))
    entry, kind = store.lookup(BASE)
    assert kind == "exact"
    np.testing.assert_allclose(entry.metric, _metric(BASE, base_champion))
    assert len(store) == 1


def test_store_persistence_round_trip(tmp_path, base_champion):
    store = ChampionStore()
    store.put(BASE, base_champion, _metric(BASE, base_champion),
              np.asarray(O.evaluate(BASE, base_champion)),
              provenance={"algo": "nsga2", "seed": 0})
    path = str(tmp_path / "champions.json")
    store.save(path)
    with open(path) as f:
        assert json.load(f)["champion_store"] == 1
    loaded = ChampionStore(path=path)
    entry, kind = loaded.lookup(BASE)
    assert kind == "exact" and entry.provenance["algo"] == "nsga2"
    for tier in ("dist", "loc", "perm"):
        for t in range(3):
            np.testing.assert_array_equal(
                entry.genotype[tier][t], np.asarray(base_champion[tier][t]))
    # the restored champion still serves as a legal warm seed
    O.assert_valid(BASE, entry.genotype)
    np.testing.assert_allclose(_metric(BASE, entry.genotype), entry.metric,
                               rtol=1e-6)


def test_exact_hit_serves_without_slot(base_champion):
    store = ChampionStore()
    store.put(BASE, base_champion, _metric(BASE, base_champion),
              np.asarray(O.evaluate(BASE, base_champion)))
    sch = PlacementScheduler(n_slots=2, gens_per_step=2, store=store)
    target = _metric(BASE, base_champion) * 1.001
    jid = sch.submit("xcvu_test", nsga2.NSGA2Config(pop_size=8),
                     seed=3, budget=32, target=target)
    # answered at submit: no pool was created, no slot burned
    assert sch.stats()["n_pools"] == 0
    (job,) = sch.run_all()
    assert job.jid == jid and job.cached and job.done
    assert job.result.gens == 0
    assert job.result.metric <= target
    O.assert_valid(BASE, job.result.genotype)
    assert sch.stats()["n_pools"] == 0               # still no pool


def test_sibling_hit_warm_beats_cold(base_champion):
    """The store discovers the xcvu_test champion as a warm-start donor
    for xcvu_test2 (sibling signature) and the warm job reaches the
    migrated champion's metric in strictly fewer generations."""
    store = ChampionStore()
    store.put(BASE, base_champion, _metric(BASE, base_champion),
              np.asarray(O.evaluate(BASE, base_champion)))
    g_mig = transfer.migrate(BASE, SIB, base_champion)
    target = _metric(SIB, g_mig)

    cold = PlacementScheduler(n_slots=1, gens_per_step=2)   # no store
    cold.submit("xcvu_test2", nsga2.NSGA2Config(pop_size=16),
                seed=0, budget=60, target=target)
    (cold_job,) = cold.run_all()

    warm = PlacementScheduler(n_slots=1, gens_per_step=2, store=store)
    jid = warm.submit("xcvu_test2", nsga2.NSGA2Config(pop_size=16),
                      seed=0, budget=60, target=target)
    (warm_job,) = warm.run_all()
    assert warm_job.jid == jid
    assert warm_job.warm_from_cache and not warm_job.cached
    assert warm_job.result.metric <= target
    assert warm_job.result.gens < cold_job.result.gens, (
        f"warm {warm_job.result.gens} !< cold {cold_job.result.gens}")
    # the sibling result wrote back under SIB's own signature
    entry, kind = store.lookup(SIB)
    assert kind == "exact" and entry.device_name == "xcvu_test2"


def test_cache_disabled_matches_pr2_behaviour():
    """store=None must leave the scheduler bitwise identical to routing
    straight into a standalone service pool."""
    spec = dict(seed=5, budget=6,
                cfg=nsga2.NSGA2Config(pop_size=8, sbx_eta=7.0))
    ref = PlacementService(SIB, spec["cfg"], n_slots=2, gens_per_step=2)
    (ref_job,) = ref.run_jobs([spec])
    sch = PlacementScheduler(n_slots=2, gens_per_step=2)
    jid = sch.submit("xcvu_test2", spec["cfg"], seed=5, budget=6)
    done = {j.jid: j for j in sch.run_all()}
    np.testing.assert_array_equal(done[jid].result.best_objs,
                                  ref_job.best_objs)


def test_explicit_init_state_wins_over_cache(base_champion):
    """A store injects init_state ONLY when the caller left it unset, and
    an explicit init_state wins over the cache."""
    g_explicit = G.random_genotype(KEY, BASE)
    store = ChampionStore()
    store.put(BASE, base_champion, _metric(BASE, base_champion),
              np.asarray(O.evaluate(BASE, base_champion)))
    sch = PlacementScheduler(n_slots=1, gens_per_step=2, store=store)
    jid = sch.submit("xcvu_test", nsga2.NSGA2Config(pop_size=8),
                     seed=2, budget=4, init_state=g_explicit)
    done = {j.jid: j for j in sch.run_all()}
    assert not done[jid].warm_from_cache
    ref = PlacementService(BASE, nsga2.NSGA2Config(pop_size=8),
                           n_slots=1, gens_per_step=2)
    (ref_job,) = ref.run_jobs([dict(seed=2, budget=4,
                                    init_state=g_explicit)])
    np.testing.assert_array_equal(done[jid].result.best_objs,
                                  ref_job.best_objs)


# --------------------------------------------------------------- policies

def _view(key, steppable, jobs, index=0):
    return PoolView(key=key, index=index, steppable=steppable,
                    queue_depth=0, jobs=jobs)


class _J:
    def __init__(self, priority=0.0, deadline=None):
        self.priority, self.deadline = priority, deadline


def test_round_robin_pointer_advances_past_stepped_pools():
    rr = RoundRobinPolicy()
    views = [_view("a", True, []), _view("b", True, []),
             _view("c", False, [])]
    picks = [rr.select(views) for _ in range(4)]
    assert picks == [0, 1, 0, 1]                    # c never steppable
    views[2] = _view("c", True, [])
    assert rr.select(views) == 2                     # c's turn comes


def test_priority_and_deadline_policies_order():
    pr = PriorityPolicy()
    views = [_view("a", True, [_J(priority=1.0)]),
             _view("b", True, [_J(priority=5.0)])]
    assert pr.select(views) == 1
    edf = DeadlinePolicy()
    views = [_view("a", True, [_J(deadline=None)]),
             _view("b", True, [_J(deadline=9.0), _J(deadline=2.0)]),
             _view("c", True, [_J(deadline=5.0)])]
    assert edf.select(views) == 1                    # min deadline 2.0
    with pytest.raises(KeyError):
        get_policy("nope")


def test_scheduler_fairness_two_uneven_pools():
    """Regression: a small pool behind a perpetually busy pool must not
    starve -- with round-robin both pools step alternately, so the small
    pool's jobs finish long before the busy pool drains."""
    sch = PlacementScheduler(n_slots=2, gens_per_step=2)
    big = nsga2.NSGA2Config(pop_size=16)
    small = nsga2.NSGA2Config(pop_size=8)
    for s in range(6):           # pool A: always busy (6 jobs, 2 slots)
        sch.submit("xcvu_test", big, seed=s, budget=8)
    jids_b = [sch.submit("xcvu_test", small, seed=s, budget=4)
              for s in range(2)]
    done_at = {}
    t = 0
    while sch.busy:
        t += 1
        for j in sch.step():
            done_at[j.jid] = t
    # pool B needed 2 of its own steps; fair alternation finishes it
    # within ~4 fleet steps -- starvation would push it past pool A
    assert all(done_at[j] <= 6 for j in jids_b), done_at
    assert max(done_at[j] for j in jids_b) < max(done_at.values())


def test_deadline_policy_beats_round_robin_for_urgent_job():
    """An urgent (tight-deadline) job submitted AFTER bulk work finishes
    first under EDF, and does not under plain round-robin."""
    bulk_cfg = nsga2.NSGA2Config(pop_size=16)
    urgent_cfg = nsga2.NSGA2Config(pop_size=8)

    def run(policy):
        sch = PlacementScheduler(n_slots=1, gens_per_step=2, policy=policy)
        bulk = [sch.submit("xcvu_test", bulk_cfg, seed=s, budget=4)
                for s in range(2)]
        urgent = sch.submit("xcvu_test", urgent_cfg, seed=0, budget=4,
                            deadline=1.0)
        order = [j.jid for j in sch.run_all()]
        return order.index(urgent), [order.index(b) for b in bulk]

    edf_urgent, edf_bulk = run("deadline")
    rr_urgent, rr_bulk = run("round_robin")
    assert edf_urgent < min(edf_bulk)                # EDF: urgent first
    assert rr_urgent > min(rr_bulk)                  # RR interleaves


def test_priority_policy_prefers_high_priority_pool():
    sch = PlacementScheduler(n_slots=1, gens_per_step=2, policy="priority")
    lo = sch.submit("xcvu_test", nsga2.NSGA2Config(pop_size=16),
                    seed=0, budget=4, priority=0.0)
    hi = sch.submit("xcvu_test", nsga2.NSGA2Config(pop_size=8),
                    seed=0, budget=4, priority=10.0)
    order = [j.jid for j in sch.run_all()]
    assert order.index(hi) < order.index(lo)


def test_policy_changes_order_not_results():
    spec = dict(seed=4, budget=6, cfg=nsga2.NSGA2Config(pop_size=8))
    results = {}
    for policy in ("round_robin", "deadline", "priority"):
        sch = PlacementScheduler(n_slots=1, gens_per_step=2, policy=policy)
        jid = sch.submit("xcvu_test", spec["cfg"], seed=4, budget=6,
                         deadline=5.0, priority=1.0)
        sch.submit("xcvu_test", nsga2.NSGA2Config(pop_size=16), seed=1,
                   budget=4)
        done = {j.jid: j for j in sch.run_all()}
        results[policy] = done[jid].result.best_objs
    np.testing.assert_array_equal(results["round_robin"],
                                  results["deadline"])
    np.testing.assert_array_equal(results["round_robin"],
                                  results["priority"])


# ------------------------------------------------------------ elasticity

def test_grow_carries_live_jobs_and_matches_standalone():
    specs = [dict(seed=i, budget=6, cfg=nsga2.NSGA2Config(pop_size=8))
             for i in range(4)]
    ref = PlacementService(BASE, nsga2.NSGA2Config(pop_size=8),
                           n_slots=1, gens_per_step=2)
    ref_objs = {j.seed: j.best_objs for j in ref.run_jobs(list(specs))}

    svc = PlacementService(BASE, nsga2.NSGA2Config(pop_size=8),
                           n_slots=1, gens_per_step=2)
    assert svc.submit(**specs[0]) is not None
    assert svc.submit(**specs[1]) is None            # full at 1 slot
    svc.step()                                       # job 0 mid-flight
    svc.grow(2)
    svc.grow(4)
    for s in specs[1:]:
        assert svc.submit(**s) is not None
    done = []
    while svc.active.any():
        done.extend(svc.step())
    assert len(done) == 4
    assert svc.size_history == [1, 2, 4]
    for j in done:
        np.testing.assert_allclose(j.best_objs, ref_objs[j.seed],
                                   rtol=1e-5)
    # one compile per ladder size at most (-1 = counter unavailable)
    assert svc.step_compiles in (-1, 2, 3)
    with pytest.raises(ValueError):
        svc.grow(2)


def test_scheduler_autoscales_on_queue_depth():
    sch = PlacementScheduler(n_slots=1, gens_per_step=2, autoscale=True,
                             autoscale_threshold=2, max_slots=4)
    jids = [sch.submit("xcvu_test", nsga2.NSGA2Config(pop_size=8),
                       seed=i, budget=4) for i in range(6)]
    done = {j.jid: j for j in sch.run_all()}
    assert sorted(done) == jids
    assert sch.autoscale_events, "queue depth 5 >= 2 must trigger growth"
    (label,) = sch.stats()["pools"]
    pool_stats = sch.stats()["pools"][label]
    sizes = pool_stats["sizes"]
    assert sizes[0] == 1 and sizes == sorted(sizes)
    assert all(b == 2 * a for a, b in zip(sizes, sizes[1:]))  # ladder
    assert sizes[-1] <= 4
    # at most one step compile per ladder size ever reached
    assert (pool_stats["step_compiles"] == -1
            or pool_stats["step_compiles"] <= len(sizes))
    assert pool_stats["queue_depth"] == 0
    # autoscaled results still match a standalone never-grown service
    ref = PlacementService(BASE, nsga2.NSGA2Config(pop_size=8),
                           n_slots=1, gens_per_step=2)
    ref_objs = {j.seed: j.best_objs for j in ref.run_jobs(
        [dict(seed=i, budget=4) for i in range(6)])}
    for j in done.values():
        np.testing.assert_allclose(j.result.best_objs,
                                   ref_objs[j.result.seed], rtol=1e-5)


def test_queue_depth_exposed_in_stats():
    sch = PlacementScheduler(n_slots=1, gens_per_step=2)
    for i in range(3):
        sch.submit("xcvu_test", nsga2.NSGA2Config(pop_size=8),
                   seed=i, budget=4)
    (label,) = sch.stats()["pools"]
    assert sch.stats()["pools"][label]["queue_depth"] == 2
    sch.run_all()
    assert sch.stats()["pools"][label]["queue_depth"] == 0
