"""Sharding rules, cost model, autoshard, HLO parser, elastic runtime."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs import get_arch
from repro.core import autoshard
from repro.runtime import elastic
from repro.runtime.jaxcompat import make_mesh
from repro.sharding import costmodel as cm
from repro.sharding import hloparse, logical


# ------------------------------------------------------------ logical

def _mesh22():
    return make_mesh((1, 1), ("data", "model"))


def test_spec_divisibility_fallback():
    mesh = make_mesh((1,), ("model",))
    rules = logical.Rules((("heads", "model"),))
    # size-1 axis: sharding is a no-op, the resolver replicates instead
    spec = logical.spec_for(("heads",), (56,), mesh, rules)
    assert spec == jax.sharding.PartitionSpec(None)


def test_spec_drops_nondivisible():
    import numpy as np  # noqa
    # fake a 16-wide axis via abstract check: use helper directly
    class FakeMesh:
        shape = {"model": 16}
    rules = logical.Rules((("heads", "model"),))
    spec = logical.spec_for(("heads",), (56,), FakeMesh, rules)
    assert spec == jax.sharding.PartitionSpec(None)
    spec = logical.spec_for(("heads",), (64,), FakeMesh, rules)
    assert spec == jax.sharding.PartitionSpec("model")


def test_spec_no_axis_reuse():
    class FakeMesh:
        shape = {"model": 4}
    rules = logical.Rules((("a", "model"), ("b", "model")))
    spec = logical.spec_for(("a", "b"), (8, 8), FakeMesh, rules)
    # the second dim must not reuse the spent axis
    assert spec == jax.sharding.PartitionSpec("model", None)


def test_constrain_is_identity_without_context():
    x = jnp.ones((4, 4))
    y = logical.constrain(x, "batch", "embed")
    np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_rules_override():
    r = logical.default_rules()
    r2 = r.override(kv_seq=("data", "model"))
    assert r2.get("kv_seq") == ("data", "model")
    assert r.get("kv_seq") == "model"


# ----------------------------------------------------------- costmodel

MESH = cm.MeshShape(1, 16, 16)


def test_costmodel_compute_term_matches_formula():
    cfg = get_arch("yi-6b")
    rep = cm.estimate(cfg, "train_4k", MESH)
    flops = 6 * cm._active_params(cfg) * 256 * 4096
    assert rep.compute_s == pytest.approx(flops / (256 * cm.PEAK_FLOPS))


def test_costmodel_moe_active_params():
    cfg = get_arch("deepseek-moe-16b")
    act = cm._active_params(cfg)
    tot = cfg.param_count()
    assert act < 0.35 * tot          # 6-of-64 routed + shared
    assert act > 0.05 * tot


def test_costmodel_tp_reduces_memory():
    cfg = get_arch("yi-6b")
    r16 = cm.estimate(cfg, "train_4k", cm.MeshShape(1, 16, 16))
    r4 = cm.estimate(cfg, "train_4k", cm.MeshShape(1, 64, 4),
                     {"batch": ("data",)})
    assert r16.memory_s != r4.memory_s


def test_costmodel_decode_kv_dominates():
    cfg = get_arch("mistral-large-123b")
    rep = cm.estimate(cfg, "decode_32k", MESH)
    assert rep.dominant in ("memory", "collective", "compute")
    assert rep.bytes_per_device > 0


# ----------------------------------------------------------- autoshard

def test_autoshard_beats_or_matches_baseline():
    cfg = get_arch("deepseek-moe-16b")
    res = autoshard.search(cfg, "train_4k", MESH, pop_size=16, n_gens=10)
    assert res.best_report.step_s <= res.baseline.step_s * 1.0001
    assert res.evaluations >= 16 * 10


def test_autoshard_respects_hbm_limit():
    # feasible case: the champion must sit under the limit
    cfg = get_arch("yi-6b")
    res = autoshard.search(cfg, "train_4k", MESH, pop_size=16, n_gens=10,
                           hbm_limit=64e9)
    assert res.best_report.bytes_per_device <= 64e9 * 1.05
    # infeasible case (123B under 64GB w/ honest replication accounting):
    # search still returns the least-bad layout instead of crashing
    big = get_arch("mistral-large-123b")
    res2 = autoshard.search(big, "train_4k", MESH, pop_size=16, n_gens=10,
                            hbm_limit=64e9)
    assert res2.best_report.bytes_per_device > 0


def test_autoshard_genotype_roundtrip():
    rules = autoshard.genotype_to_rules([0, 0, 0, 0])
    assert rules["batch"] == ("data",)
    log = autoshard.rules_to_logical(rules, multi_pod=False)
    assert log.get("batch") == ("data",)


@settings(max_examples=20, deadline=None)
@given(genes=st.lists(st.integers(0, 10), min_size=4, max_size=4))
def test_autoshard_any_genotype_is_legal(genes):
    rules = autoshard.genotype_to_rules(genes)
    assert set(rules) == {s for s, _ in autoshard.SITES}


# ------------------------------------------------------------ hloparse

def test_hloparse_scanned_matmul_flops_exact():
    def f(w, x):
        def body(c, wi):
            return jnp.tanh(c @ wi), ()
        return jax.lax.scan(body, x, w)[0].sum()

    comp = jax.jit(f).lower(
        jax.ShapeDtypeStruct((6, 64, 64), jnp.float32),
        jax.ShapeDtypeStruct((8, 64), jnp.float32)).compile()
    res = hloparse.analyze(comp.as_text())
    assert res["flops"] == pytest.approx(6 * 2 * 8 * 64 * 64, rel=0.01)


def test_hloparse_trip_count_scaling():
    def f_n(n):
        def f(w, x):
            def body(c, wi):
                return jnp.tanh(c @ wi), ()
            return jax.lax.scan(body, x, w)[0].sum()
        comp = jax.jit(f).lower(
            jax.ShapeDtypeStruct((n, 64, 64), jnp.float32),
            jax.ShapeDtypeStruct((8, 64), jnp.float32)).compile()
        return hloparse.analyze(comp.as_text())["flops"]

    assert f_n(12) == pytest.approx(2 * f_n(6), rel=0.05)


def test_hloparse_shape_bytes():
    def tot(s):
        return sum(b for _, b, _ in hloparse._shape_list(s))
    assert tot("bf16[4,8]") == 64
    assert tot("(f32[2,2], s32[3])") == 28
    assert tot("pred[]") == 1


# ------------------------------------------------------------- elastic

def test_failure_detector():
    fd = elastic.FailureDetector(["h0", "h1", "h2"], timeout_s=5)
    for h in ("h0", "h1", "h2"):
        fd.beat(h, now=100.0)
    fd.beat("h0", now=104.0)
    assert fd.dead(now=107.0) == ["h1", "h2"]
    assert fd.alive(now=107.0) == ["h0"]


def test_remesh_preserves_model_parallel():
    plan = elastic.remesh_plan(200, model_parallel=16)
    assert plan.shape == (12, 16)
    assert plan.dropped_hosts == 200 - 12 * 16
    plan2 = elastic.remesh_plan(500, model_parallel=16, pods=2)
    assert plan2.shape == (2, 15, 16)
    with pytest.raises(RuntimeError):
        elastic.remesh_plan(8, model_parallel=16)


def test_straggler_monitor():
    m = elastic.StragglerMonitor(window=20, ratio=2.0)
    for _ in range(18):
        m.record(1.0)
    assert not m.straggling()
    for _ in range(2):
        m.record(10.0)
    assert m.straggling()
    assert m.recommendation() == "rebalance"
