"""Portfolio runner + placement service: batching must not change answers.

Covers the tentpole's two contracts:
  * `run_portfolio` (K configs in ONE vmapped jitted program) returns, per
    member, exactly what K independent `evolve.run` calls return with the
    same keys -- history, best objectives, and champion.
  * `PlacementService` finishes every submitted job with a legal placement
    while the batched `step()` program compiles exactly once across the
    whole job stream (continuous batching, static shapes).
"""
import jax
import numpy as np
import pytest

from repro.core import cmaes, evolve, hyper, nsga2, portfolio
from repro.core import objectives as O
from repro.fpga import device, netlist
from repro.serve.placement_service import PlacementService

PROB = netlist.make_problem(device.get_device("xcvu_test"))
KEY = jax.random.PRNGKey(0)

CFGS = [nsga2.NSGA2Config(pop_size=8, sbx_eta=e, real_mut_prob=m)
        for e, m in [(15.0, 0.1), (5.0, 0.2), (25.0, 0.05), (15.0, 0.3)]]


# ------------------------------------------------------------- portfolio

def test_portfolio_matches_independent_runs():
    keys = jax.random.split(KEY, len(CFGS))
    res = portfolio.run_portfolio(PROB, "nsga2", CFGS, keys=keys, n_gens=6)
    ind_best = []
    for i, (cfg, k) in enumerate(zip(CFGS, keys)):
        st, hist = evolve.run(PROB, "nsga2", cfg, k, 6)
        np.testing.assert_allclose(res.history[i], np.asarray(hist),
                                   rtol=1e-5)
        ind_best.append(np.asarray(evolve.state_best_objs(st)))
    ind_best = np.stack(ind_best)
    np.testing.assert_allclose(res.best_objs, ind_best, rtol=1e-5)
    assert res.champion == int(np.argmin(O.combined_metric(ind_best)))


def test_portfolio_rejects_mixed_static_fields():
    with pytest.raises(ValueError):
        hyper.stack_configs([nsga2.NSGA2Config(pop_size=8),
                             nsga2.NSGA2Config(pop_size=16)])


def test_float_fields_classified_by_annotation_not_value():
    # sbx_eta=20 (a Python int) is still a float *field*: it must land on
    # the traced side, identical to sbx_eta=20.0, not become a static key
    sk_int, tr_int = hyper.split_config(nsga2.NSGA2Config(sbx_eta=20))
    sk_flt, tr_flt = hyper.split_config(nsga2.NSGA2Config(sbx_eta=20.0))
    assert sk_int == sk_flt and tr_int == tr_flt
    hyper.stack_configs([nsga2.NSGA2Config(sbx_eta=20),
                         nsga2.NSGA2Config(sbx_eta=20.0)])


def test_race_early_stops_and_improves():
    rr = portfolio.race(PROB, "nsga2", CFGS, KEY, max_gens=40,
                        gens_per_round=4, patience=1)
    assert 1 <= rr.rounds <= 10 and rr.gens == rr.rounds * 4
    assert rr.history.shape == (rr.rounds, len(CFGS), 2)
    # champion is the argmin of the final per-member metrics
    assert rr.champion == int(np.argmin(rr.metric))
    # racing never makes the champion worse than round 0's best
    assert rr.metric[rr.champion] <= np.min(O.combined_metric(rr.history[0]))
    g, objs = portfolio.best_genotype(
        PROB, "nsga2", rr.member_state(rr.champion), CFGS[rr.champion])
    O.assert_valid(PROB, g)
    assert np.isfinite(np.asarray(objs)).all()


def test_reduced_portfolio_champion_genotype_legal():
    cfgs = [nsga2.NSGA2Config(pop_size=8, reduced=True, perm_swap_prob=p)
            for p in (0.4, 0.8)]
    res = portfolio.run_portfolio(PROB, "nsga2", cfgs, key=KEY, n_gens=4)
    g, _ = portfolio.best_genotype(
        PROB, "nsga2", res.member_state(res.champion), cfgs[res.champion])
    O.assert_valid(PROB, g)


# --------------------------------------------------------------- service

def test_service_finishes_jobs_legal_recompile_free():
    svc = PlacementService(PROB, nsga2.NSGA2Config(pop_size=8),
                           n_slots=3, gens_per_step=2)
    specs = [dict(seed=i, budget=4 if i % 2 else 6,
                  cfg=nsga2.NSGA2Config(pop_size=8,
                                        real_mut_prob=0.05 + 0.05 * i))
             for i in range(5)]
    done = svc.run_jobs(specs)
    assert len(done) == 5 and all(j.done for j in done)
    for j in done:
        assert j.gens == j.budget
        assert np.isfinite(j.best_objs).all()
        O.assert_valid(PROB, j.genotype)
    # continuous batching: jobs came and went, ONE compiled step program
    assert svc.step_compiles == 1
    assert svc.stats()["useful_gens"] == sum(s["budget"] for s in specs)


def test_service_backpressure_and_pool_isolation():
    svc = PlacementService(PROB, nsga2.NSGA2Config(pop_size=8), n_slots=2,
                           gens_per_step=2)
    assert svc.submit(budget=4) is not None
    assert svc.submit(budget=4) is not None
    assert svc.submit(budget=4) is None          # pool full -> backpressure
    # a config with different static fields cannot join this pool
    with pytest.raises(ValueError):
        svc.submit(cfg=nsga2.NSGA2Config(pop_size=16))
    while svc.active.any():
        svc.step()
    assert svc.step_compiles == 1


def test_service_jobs_reproducible_regardless_of_cotenants():
    """A job's result is a pure function of (cfg, seed, budget,
    gens_per_step): same spec alone or on a loaded pool, same answer."""
    spec = dict(seed=42, budget=4,
                cfg=nsga2.NSGA2Config(pop_size=8, real_mut_prob=0.2))
    alone = PlacementService(PROB, nsga2.NSGA2Config(pop_size=8),
                             n_slots=1, gens_per_step=2)
    (job_a,) = [j for j in alone.run_jobs([spec]) if j.seed == 42]
    crowded = PlacementService(PROB, nsga2.NSGA2Config(pop_size=8),
                               n_slots=3, gens_per_step=2)
    others = [dict(seed=7 + i, budget=6) for i in range(4)]
    done = crowded.run_jobs(others[:2] + [spec] + others[2:])
    (job_b,) = [j for j in done if j.seed == 42]
    np.testing.assert_array_equal(job_a.best_objs, job_b.best_objs)


def test_service_target_metric_finishes_early():
    svc = PlacementService(PROB, nsga2.NSGA2Config(pop_size=8), n_slots=1,
                           gens_per_step=2)
    svc.submit(seed=0, budget=50, target=float("inf"))
    done = svc.step()                            # any metric beats +inf
    assert len(done) == 1 and done[0].gens == 2 < 50


def test_service_cmaes_pool():
    svc = PlacementService(PROB, cmaes.CMAESConfig(pop_size=8),
                           algo="cmaes", n_slots=2, gens_per_step=3)
    done = svc.run_jobs([dict(seed=i, budget=6) for i in range(3)])
    assert len(done) == 3
    for j in done:
        O.assert_valid(PROB, j.genotype)
    assert svc.step_compiles == 1


# ------------------------------------------------- fused-eval regression

def test_fused_flag_is_static_pool_identity():
    """`fused` is a bool config field -> part of the static key: fused and
    unfused jobs cannot share a pool."""
    sk_u, tr_u = hyper.split_config(nsga2.NSGA2Config(pop_size=8))
    sk_f, tr_f = hyper.split_config(
        nsga2.NSGA2Config(pop_size=8, fused=True))
    assert sk_u != sk_f and tr_u == tr_f
    svc = PlacementService(PROB, nsga2.NSGA2Config(pop_size=8), n_slots=1)
    with pytest.raises(ValueError):
        svc.submit(cfg=nsga2.NSGA2Config(pop_size=8, fused=True))


def test_portfolio_fused_matches_unfused_bitwise():
    """On the CPU dispatch both paths run the same ref oracles: the fused
    portfolio must reproduce the unfused histories and champions exactly."""
    fused_cfgs = [
        nsga2.NSGA2Config(pop_size=c.pop_size, sbx_eta=c.sbx_eta,
                          real_mut_prob=c.real_mut_prob, fused=True)
        for c in CFGS]
    keys = jax.random.split(KEY, len(CFGS))
    res_u = portfolio.run_portfolio(PROB, "nsga2", CFGS, keys=keys, n_gens=5)
    res_f = portfolio.run_portfolio(PROB, "nsga2", fused_cfgs, keys=keys,
                                    n_gens=5)
    np.testing.assert_array_equal(res_u.history, res_f.history)
    np.testing.assert_array_equal(res_u.best_objs, res_f.best_objs)
    assert res_u.champion == res_f.champion


def test_service_fused_matches_unfused_champions():
    """Same job stream through a fused and an unfused pool: every job's
    harvested champion objectives agree."""

    def run(fused):
        svc = PlacementService(
            PROB, nsga2.NSGA2Config(pop_size=8, fused=fused),
            n_slots=2, gens_per_step=2)
        specs = [dict(seed=i, budget=4,
                      cfg=nsga2.NSGA2Config(pop_size=8,
                                            real_mut_prob=0.1 + 0.05 * i,
                                            fused=fused))
                 for i in range(4)]
        done = svc.run_jobs(specs)
        assert svc.step_compiles == 1
        return {j.seed: j.best_objs for j in done}

    cold, hot = run(False), run(True)
    assert cold.keys() == hot.keys()
    for seed in cold:
        np.testing.assert_array_equal(cold[seed], hot[seed])


def test_service_fused_cmaes_and_sa_pools():
    """The fused flag rides every algorithm config, not just NSGA-II."""
    svc = PlacementService(PROB, cmaes.CMAESConfig(pop_size=8, fused=True),
                           algo="cmaes", n_slots=1, gens_per_step=2)
    done = svc.run_jobs([dict(seed=0, budget=4)])
    assert len(done) == 1 and np.isfinite(done[0].best_objs).all()
    O.assert_valid(PROB, done[0].genotype)
