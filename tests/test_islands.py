"""Island-model evolution subsystem (`core.islands`).

Covers the contracts the serving stack leans on:
  * degeneracy -- islands(P=1) is bitwise the single-population
    `evolve.run` (full state for nsga2/ga/cmaes; SA's chain position may
    differ in the last ulp because vmap turns its `lax.switch` move into
    compute-all-branches-and-select, but every observable -- history,
    fitness, best state -- stays bitwise),
  * determinism -- islands results are a pure function of (config, seed,
    budget, init_state, island config): same seed twice is bitwise equal,
  * migration -- the champion ring moves island i's champion to island
    i+1 (replace-worst for populations), boundaries counted in *global*
    generations so chunked service rounds migrate on the same schedule,
  * service -- an islands pool keeps the single-compile discipline, P=1
    pools match plain pools, warm seeds land on island 0 and diffuse,
  * sharding (`multidevice`) -- the shard_map + ppermute path computes
    the same result as the single-device vmap stack.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import annealing, cmaes, evolve, ga, nsga2
from repro.core import genotype as G
from repro.core import islands as I
from repro.core import objectives as O
from repro.core.islands import IslandConfig
from repro.serve.placement_service import PlacementService

KEY = jax.random.PRNGKey(0)
P4 = IslandConfig(n_islands=4, migrate_every=2)


def _assert_leaves(tree_a, tree_b, island=None, exact=True):
    for a, b in zip(jax.tree.leaves(tree_a), jax.tree.leaves(tree_b)):
        b = np.asarray(b) if island is None else np.asarray(b)[island]
        if exact:
            np.testing.assert_array_equal(np.asarray(a), b)
        else:
            np.testing.assert_allclose(np.asarray(a), b, rtol=1e-6)


# ------------------------------------------------------------ degeneracy

@pytest.mark.parametrize("algo,cfg", [
    ("nsga2", nsga2.NSGA2Config(pop_size=8)),
    ("nsga2", nsga2.NSGA2Config(pop_size=8, reduced=True)),
    ("ga", ga.GAConfig(pop_size=8)),
    ("cmaes", cmaes.CMAESConfig(pop_size=8)),
])
def test_p1_bitwise_identity(small_problem, algo, cfg):
    st_s, h_s = evolve.run(small_problem, algo, cfg, KEY, 5)
    st_i, h_i = evolve.run(small_problem, algo, cfg, KEY, 5,
                           islands=IslandConfig(1, 0))
    np.testing.assert_array_equal(np.asarray(h_s), np.asarray(h_i)[:, 0])
    _assert_leaves(st_s, st_i, island=0)


def test_p1_identity_sa(small_problem):
    cfg = annealing.SAConfig()
    st_s, h_s = evolve.run(small_problem, "sa", cfg, KEY, 5)
    st_i, h_i = evolve.run(small_problem, "sa", cfg, KEY, 5,
                           islands=IslandConfig(1, 0))
    np.testing.assert_array_equal(np.asarray(h_s), np.asarray(h_i)[:, 0])
    for k in st_s:
        a, b = np.asarray(st_s[k]), np.asarray(st_i[k])[0]
        if k == "z":   # vmapped lax.switch: last-ulp chain-position drift
            np.testing.assert_allclose(a, b, atol=1e-6)
        else:
            np.testing.assert_array_equal(a, b)


def test_island_keys_p1_is_callers_key():
    keys = I.island_keys(KEY, 1)
    np.testing.assert_array_equal(np.asarray(keys[0]), np.asarray(KEY))
    assert I.island_keys(KEY, 4).shape[0] == 4


def test_invalid_island_config():
    with pytest.raises(ValueError):
        IslandConfig(n_islands=0)
    with pytest.raises(ValueError):
        IslandConfig(n_islands=2, migrate_every=-1)


# ----------------------------------------------------------- determinism

def test_same_seed_bitwise_identical(small_problem):
    cfg = nsga2.NSGA2Config(pop_size=8)
    st1, h1 = evolve.run(small_problem, "nsga2", cfg, KEY, 6, islands=P4)
    st2, h2 = evolve.run(small_problem, "nsga2", cfg, KEY, 6, islands=P4)
    np.testing.assert_array_equal(np.asarray(h1), np.asarray(h2))
    _assert_leaves(st1, st2)
    assert np.asarray(h1).shape == (6, 4, 2)
    c = np.asarray(O.combined_metric(jnp.asarray(h1)))
    assert c[-1].min() <= c[0].min()


# ------------------------------------------------------------- migration

def _stacked_state(problem, n_islands, pop=6):
    cfg = nsga2.NSGA2Config(pop_size=pop)
    keys = jax.random.split(KEY, n_islands)
    return jax.vmap(
        lambda k: nsga2.init_state(problem, k, cfg))(keys)


def test_ring_moves_champion_to_right_neighbour(small_problem):
    state = _stacked_state(small_problem, 4)
    champs, cobjs = jax.vmap(I.champion)(state)
    worst = np.asarray(jax.vmap(
        lambda s: jnp.argmax(O.combined_metric(s["objs"])))(state))
    out = I.migrate_ring(state)
    for i in range(4):
        src = (i - 1) % 4
        # island i's former worst row now holds island i-1's champion
        np.testing.assert_array_equal(
            np.asarray(out["objs"])[i, worst[i]], np.asarray(cobjs)[src])
        for a, b in zip(jax.tree.leaves(
                jax.tree.map(lambda x: x[i, worst[i]], out["pop"])),
                jax.tree.leaves(jax.tree.map(lambda x: x[src], champs))):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_planted_champion_diffuses_around_ring(small_problem):
    """A super-champion planted on island 0 walks one hop per migration."""
    state = _stacked_state(small_problem, 4)
    best = jnp.asarray([0.0, 0.0], jnp.float32)   # unbeatable objectives
    state["objs"] = state["objs"].at[0, 0].set(best)
    hops = state
    reached = {0}
    for _ in range(3):
        hops = I.migrate_ring(hops)
        reached = {i for i in range(4)
                   if (np.asarray(hops["objs"])[i] == 0.0).all(-1).any()}
    assert reached == {0, 1, 2, 3}


def test_point_algo_adopts_only_on_improvement(small_problem):
    cfg = cmaes.CMAESConfig(pop_size=6)
    keys = jax.random.split(KEY, 2)
    state = jax.vmap(
        lambda k: cmaes.init_state(small_problem, k, cfg))(keys)
    state["best_objs"] = jnp.asarray([[1.0, 1.0], [2.0, 2.0]], jnp.float32)
    out = I.migrate_ring(state)
    # island 1 (worse) adopts island 0's champion; island 0 keeps its own
    np.testing.assert_array_equal(np.asarray(out["best_objs"][1]),
                                  np.asarray(state["best_objs"][0]))
    np.testing.assert_array_equal(np.asarray(out["best_z"][1]),
                                  np.asarray(state["best_z"][0]))
    np.testing.assert_array_equal(np.asarray(out["best_objs"][0]),
                                  np.asarray(state["best_objs"][0]))
    np.testing.assert_array_equal(np.asarray(out["mean"][1]),
                                  np.asarray(state["best_z"][0]))


def test_migration_counts_global_generations(small_problem):
    """round_impl chunked as 2+2 gens with carried g0 equals one 4-gen
    call: the service's gens_per_step chunking cannot shift migration
    boundaries."""
    cfg = nsga2.NSGA2Config(pop_size=6)
    icfg = IslandConfig(4, 2)
    state = _stacked_state(small_problem, 4)
    gen_keys = jnp.stack([jax.random.split(jax.random.fold_in(KEY, g), 4)
                          for g in range(4)])
    whole, _ = I.round_impl(small_problem, "nsga2", icfg, cfg, state,
                            gen_keys, jnp.int32(0))
    half, _ = I.round_impl(small_problem, "nsga2", icfg, cfg, state,
                           gen_keys[:2], jnp.int32(0))
    chunked, _ = I.round_impl(small_problem, "nsga2", icfg, cfg, half,
                              gen_keys[2:], jnp.int32(2))
    _assert_leaves(whole, chunked)


# --------------------------------------------------------------- service

def _drain(svc):
    done = []
    while svc.active.any():
        done.extend(svc.step())
    return done


def test_islands_pool_single_compile_and_reproducible(small_problem):
    cfg = nsga2.NSGA2Config(pop_size=6)
    svc = PlacementService(small_problem, cfg, n_slots=2, gens_per_step=2,
                           islands=IslandConfig(4, 2))
    # rolling admission: 4 jobs through 2 slots, one compiled step
    done = {j.seed: j for j in svc.run_jobs(
        [dict(seed=s, budget=4) for s in range(4)])}
    assert len(done) == 4 and svc.step_compiles in (1, -1)
    assert svc.stats()["n_islands"] == 4

    svc2 = PlacementService(small_problem, cfg, n_slots=2, gens_per_step=2,
                            islands=IslandConfig(4, 2))
    (again,) = svc2.run_jobs([dict(seed=1, budget=4)])
    np.testing.assert_array_equal(again.best_objs, done[1].best_objs)
    _assert_leaves(again.genotype, done[1].genotype)


def test_p1_pool_matches_plain_pool(small_problem):
    cfg = nsga2.NSGA2Config(pop_size=6)
    plain = PlacementService(small_problem, cfg, n_slots=1,
                             gens_per_step=2)
    isl = PlacementService(small_problem, cfg, n_slots=1, gens_per_step=2,
                           islands=IslandConfig(1, 0))
    (a,) = plain.run_jobs([dict(seed=0, budget=4)])
    (b,) = isl.run_jobs([dict(seed=0, budget=4)])
    np.testing.assert_array_equal(a.best_objs, b.best_objs)
    _assert_leaves(a.genotype, b.genotype)


def test_warm_seed_lands_on_island0(small_problem):
    cfg = nsga2.NSGA2Config(pop_size=6)
    svc = PlacementService(small_problem, cfg, n_slots=1, gens_per_step=2,
                           islands=IslandConfig(4, 2))
    g = G.random_genotype(jax.random.PRNGKey(9), small_problem)
    svc.submit(seed=0, budget=4, init_state=g, jitter=0.0)
    # before stepping: island 0 row 0 of slot 0 is the unperturbed seed,
    # and with jitter=0 every island-0 row is an exact copy
    slot0 = jax.tree.map(lambda a: a[0], svc.states)
    _assert_leaves(g, jax.tree.map(lambda a: a[0, 0], slot0["pop"]))
    _drain(svc)


# -------------------------------------------------------------- sharding

@pytest.mark.multidevice
def test_sharded_islands_match_vmap(small_problem, island_mesh):
    """The shard_map + boundary-ppermute ring computes the same states
    and history as the single-device vmap stack."""
    ndev = jax.device_count()
    icfg = IslandConfig(n_islands=ndev, migrate_every=2)
    cfg = nsga2.NSGA2Config(pop_size=6)
    st_v, h_v = I.run(small_problem, "nsga2", cfg, KEY, 6, islands=icfg,
                      shard=False)
    st_s, h_s = I.run(small_problem, "nsga2", cfg, KEY, 6, islands=icfg,
                      mesh=island_mesh)
    np.testing.assert_allclose(np.asarray(h_v), np.asarray(h_s),
                               rtol=1e-6)
    _assert_leaves(st_v, st_s, exact=False)


@pytest.mark.multidevice
def test_auto_shard_is_deterministic(small_problem):
    """shard='auto' (islands divisible by device count) stays a pure
    function of the inputs."""
    icfg = IslandConfig(n_islands=jax.device_count(), migrate_every=2)
    cfg = nsga2.NSGA2Config(pop_size=6)
    st1, h1 = I.run(small_problem, "nsga2", cfg, KEY, 4, islands=icfg)
    st2, h2 = I.run(small_problem, "nsga2", cfg, KEY, 4, islands=icfg)
    np.testing.assert_array_equal(np.asarray(h1), np.asarray(h2))
    _assert_leaves(st1, st2)


def test_mesh_without_islands_axis_rejected(small_problem):
    from repro.runtime.jaxcompat import make_mesh
    mesh = make_mesh((1,), ("data",))
    with pytest.raises(ValueError):
        I.run(small_problem, "nsga2", nsga2.NSGA2Config(pop_size=6), KEY,
              2, islands=IslandConfig(2, 1), mesh=mesh)


# ------------------------------------------------- fused-eval regression

def test_islands_fused_matches_unfused(small_problem):
    """fused=True must not change island trajectories on the CPU dispatch:
    the stacked (islands x pop) batch evaluates through one fused call but
    the same oracle arithmetic."""
    cfg_u = nsga2.NSGA2Config(pop_size=8)
    cfg_f = nsga2.NSGA2Config(pop_size=8, fused=True)
    st_u, h_u = evolve.run(small_problem, "nsga2", cfg_u, KEY, 6, islands=P4)
    st_f, h_f = evolve.run(small_problem, "nsga2", cfg_f, KEY, 6, islands=P4)
    np.testing.assert_array_equal(np.asarray(h_u), np.asarray(h_f))
    _assert_leaves(st_u, st_f)


def test_islands_pool_fused_matches_unfused(small_problem):
    """An islands service pool with fused configs harvests the same
    champions as the unfused pool for the same jobs."""

    def run(fused):
        cfg = nsga2.NSGA2Config(pop_size=6, fused=fused)
        svc = PlacementService(small_problem, cfg, n_slots=2,
                               gens_per_step=2,
                               islands=IslandConfig(2, 2))
        done = svc.run_jobs([dict(seed=i, budget=4, cfg=cfg)
                             for i in range(3)])
        assert svc.step_compiles == 1
        return {j.seed: j.best_objs for j in done}

    cold, hot = run(False), run(True)
    for seed in cold:
        np.testing.assert_array_equal(cold[seed], hot[seed])
