"""Transfer learning + post-placement pipelining behaviour."""
import jax
import numpy as np
import pytest

from repro.core import evolve, genotype as G, objectives as O
from repro.core import pipelining, transfer
from repro.core.nsga2 import NSGA2Config
from repro.fpga import device, netlist

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("src,dst", [
    ("xcvu3p", "xcvu5p"),      # within family A (paper's seed grouping)
    ("xcvu11p", "xcvu13p"),    # within family B
    ("xcvu3p", "xcvu11p"),     # cross-family stress (different unit counts)
])
def test_migration_always_legal(src, dst):
    ps = netlist.make_problem(device.get_device(src))
    pd = netlist.make_problem(device.get_device(dst))
    g = G.random_genotype(KEY, ps)
    gm = transfer.migrate(ps, pd, g)
    O.assert_valid(pd, gm)


def test_same_geometry_transfer_preserves_structure():
    ps = netlist.make_problem(device.get_device("xcvu3p"))
    pd = netlist.make_problem(device.get_device("xcvu5p"))  # same family rect
    g = G.random_genotype(KEY, ps)
    gm = transfer.migrate(ps, pd, g)
    for t in range(3):
        np.testing.assert_array_equal(np.asarray(gm["perm"][t]),
                                      np.asarray(g["perm"][t]))


def test_transfer_beats_scratch_early():
    """Warm-started search reaches the seed's QoR band in far fewer
    evaluations than from scratch (paper: 11-14x) -- here we assert the
    weaker, fast-to-check property that the transfer seed starts better
    than random init."""
    prob = netlist.make_problem(device.get_device("xcvu_test"))
    state, _ = evolve.run(prob, "nsga2", NSGA2Config(pop_size=16), KEY, 30)
    g_opt = jax.tree.map(lambda a: a[0], state["pop"])
    gm = transfer.migrate(prob, prob, g_opt)   # same-device migration
    o_seed = O.combined_metric(O.evaluate(prob, gm))
    o_rand = O.combined_metric(
        O.evaluate(prob, G.random_genotype(KEY, prob)))
    assert float(o_seed) < float(o_rand)


def test_seed_population_contains_seed():
    prob = netlist.make_problem(device.get_device("xcvu_test"))
    g = G.random_genotype(KEY, prob)
    st = transfer.seed_population(prob, g, KEY, 8)
    g0 = jax.tree.map(lambda a: a[0], st["pop"])
    for t in range(3):
        np.testing.assert_array_equal(np.asarray(g0["perm"][t]),
                                      np.asarray(g["perm"][t]))
    assert st["objs"].shape == (8, 2)


def test_seed_cmaes_starts_at_seed():
    prob = netlist.make_problem(device.get_device("xcvu_test"))
    g = G.random_genotype(KEY, prob)
    state, _cfg = transfer.seed_cmaes(prob, g, KEY)
    g2 = G.from_flat(prob, state["mean"])
    for t in range(3):
        np.testing.assert_array_equal(np.asarray(g2["perm"][t]),
                                      np.asarray(g["perm"][t]))


# ------------------------------------------------------------ pipelining

def test_frequency_monotone_in_depth():
    prob = netlist.make_problem(device.get_device("xcvu_test"))
    g = G.random_genotype(KEY, prob)
    sweep = pipelining.depth_sweep(prob, g, 4)
    freqs = [sweep[d]["freq_mhz"] for d in range(5)]
    assert all(f2 >= f1 for f1, f2 in zip(freqs, freqs[1:]))
    regs = [sweep[d]["registers"] for d in range(5)]
    assert all(r2 >= r1 for r1, r2 in zip(regs, regs[1:]))


def test_auto_pipeline_hits_target():
    prob = netlist.make_problem(device.get_device("xcvu_test"))
    g = G.random_genotype(KEY, prob)
    rep = pipelining.auto_pipeline(prob, g, target_mhz=500.0)
    assert rep.freq_mhz >= 500.0
    assert rep.total_registers >= 0


def test_better_placement_needs_fewer_registers():
    """The paper's register-savings mechanism: smaller wirelength =>
    fewer pipelining registers at the same target frequency."""
    prob = netlist.make_problem(device.get_device("xcvu_test"))
    state, _ = evolve.run(prob, "nsga2", NSGA2Config(pop_size=16), KEY, 30)
    g_opt = jax.tree.map(lambda a: a[0], state["pop"])
    g_rand = G.random_genotype(jax.random.PRNGKey(77), prob)
    r_opt = pipelining.auto_pipeline(prob, g_opt, 500.0)
    r_rand = pipelining.auto_pipeline(prob, g_rand, 500.0)
    assert r_opt.total_registers <= r_rand.total_registers
