"""Transfer learning + post-placement pipelining behaviour."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import evolve, genotype as G, objectives as O
from repro.core import pipelining, transfer
from repro.core.nsga2 import NSGA2Config
from repro.fpga import device, netlist

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("src,dst", [
    ("xcvu3p", "xcvu5p"),      # within family A (paper's seed grouping)
    ("xcvu11p", "xcvu13p"),    # within family B
    ("xcvu3p", "xcvu11p"),     # cross-family stress (different unit counts)
])
def test_migration_always_legal(src, dst):
    ps = netlist.make_problem(device.get_device(src))
    pd = netlist.make_problem(device.get_device(dst))
    g = G.random_genotype(KEY, ps)
    gm = transfer.migrate(ps, pd, g)
    O.assert_valid(pd, gm)


def test_same_geometry_transfer_preserves_structure():
    ps = netlist.make_problem(device.get_device("xcvu3p"))
    pd = netlist.make_problem(device.get_device("xcvu5p"))  # same family rect
    g = G.random_genotype(KEY, ps)
    gm = transfer.migrate(ps, pd, g)
    for t in range(3):
        np.testing.assert_array_equal(np.asarray(gm["perm"][t]),
                                      np.asarray(g["perm"][t]))


def test_transfer_beats_scratch_early():
    """Warm-started search reaches the seed's QoR band in far fewer
    evaluations than from scratch (paper: 11-14x) -- here we assert the
    weaker, fast-to-check property that the transfer seed starts better
    than random init."""
    prob = netlist.make_problem(device.get_device("xcvu_test"))
    state, _ = evolve.run(prob, "nsga2", NSGA2Config(pop_size=16), KEY, 30)
    g_opt = jax.tree.map(lambda a: a[0], state["pop"])
    gm = transfer.migrate(prob, prob, g_opt)   # same-device migration
    o_seed = O.combined_metric(O.evaluate(prob, gm))
    o_rand = O.combined_metric(
        O.evaluate(prob, G.random_genotype(KEY, prob)))
    assert float(o_seed) < float(o_rand)


def test_seed_population_contains_seed():
    prob = netlist.make_problem(device.get_device("xcvu_test"))
    g = G.random_genotype(KEY, prob)
    st = transfer.seed_population(prob, g, KEY, 8)
    g0 = jax.tree.map(lambda a: a[0], st["pop"])
    for t in range(3):
        np.testing.assert_array_equal(np.asarray(g0["perm"][t]),
                                      np.asarray(g["perm"][t]))
    assert st["objs"].shape == (8, 2)


def test_seed_cmaes_starts_at_seed():
    prob = netlist.make_problem(device.get_device("xcvu_test"))
    g = G.random_genotype(KEY, prob)
    state, _cfg = transfer.seed_cmaes(prob, g, KEY)
    g2 = G.from_flat(prob, state["mean"])
    for t in range(3):
        np.testing.assert_array_equal(np.asarray(g2["perm"][t]),
                                      np.asarray(g["perm"][t]))


# ------------------------------------------------------------ pipelining

def test_frequency_monotone_in_depth():
    prob = netlist.make_problem(device.get_device("xcvu_test"))
    g = G.random_genotype(KEY, prob)
    sweep = pipelining.depth_sweep(prob, g, 4)
    freqs = [sweep[d]["freq_mhz"] for d in range(5)]
    assert all(f2 >= f1 for f1, f2 in zip(freqs, freqs[1:]))
    regs = [sweep[d]["registers"] for d in range(5)]
    assert all(r2 >= r1 for r1, r2 in zip(regs, regs[1:]))


def test_auto_pipeline_hits_target():
    prob = netlist.make_problem(device.get_device("xcvu_test"))
    g = G.random_genotype(KEY, prob)
    rep = pipelining.auto_pipeline(prob, g, target_mhz=500.0)
    assert rep.freq_mhz >= 500.0
    assert rep.total_registers >= 0


def test_vu11p_zero_stage_anchor_650mhz(vu11p_problem, monkeypatch):
    """Timing-model calibration anchor (paper Table I): the converged
    NSGA-II VU11P placement's wirelength profile -- max net ~62.6 RPM --
    reads ~650 MHz with ZERO extra pipeline stages.

    The profile is pinned rather than re-derived by search (converging
    VU11P to paper quality is CPU-infeasible in a test); what this locks
    down is the model itself: anyone re-tuning `T_BASE_NS` /
    `K_NS_PER_RPM` off the paper's operating point fails here.
    """
    ref_lens = jnp.full(vu11p_problem.n_nets, 10.0, jnp.float32)
    ref_lens = ref_lens.at[0].set(62.6)     # the converged critical net
    monkeypatch.setattr(pipelining.O, "net_lengths",
                        lambda p, g: ref_lens)
    f0 = pipelining.frequency_at_depth(vu11p_problem, None, 0)
    assert abs(f0 - 650.0) <= 10.0
    # "with zero extra stages": auto-pipelining to the paper's 650 MHz
    # target inserts no registers on the reference profile
    rep = pipelining.auto_pipeline(vu11p_problem, None, target_mhz=650.0)
    assert rep.depth == 0 and rep.total_registers == 0
    assert abs(rep.freq_mhz - 650.0) <= 10.0


def test_fmax_ceiling_never_exceeded():
    """891 MHz URAM/DSP hard Fmax: no placement and no pipelining depth
    may read above it, and infinite depth saturates exactly AT it (the
    1/T_BASE asymptote ~909 MHz sits above the ceiling)."""
    prob = netlist.make_problem(device.get_device("xcvu_test"))
    for seed in range(3):
        g = G.random_genotype(jax.random.PRNGKey(seed), prob)
        for depth in (0, 1, 2, 8, 64, 4096):
            f = pipelining.frequency_at_depth(prob, g, depth)
            assert f <= pipelining.F_CEIL_MHZ
        assert (pipelining.frequency_at_depth(prob, g, 10 ** 6)
                == pipelining.F_CEIL_MHZ)
        rep = pipelining.auto_pipeline(prob, g, target_mhz=880.0)
        assert rep.freq_mhz <= pipelining.F_CEIL_MHZ
    # targets above the model's logic floor are rejected, not clipped
    with pytest.raises(ValueError):
        pipelining.auto_pipeline(prob, g, target_mhz=1000.0)


def test_register_cost_scales_with_bus_width_and_replication():
    """Register bill = stages x net bus width x full-chip replication:
    doubling `net_bits` doubles it, tripling `n_rects` triples it, and
    it is linear in uniform depth."""
    prob = netlist.make_problem(device.get_device("xcvu_test"))
    d = 3
    base = pipelining.registers_at_depth(prob, d)
    assert base == int(prob.net_bits.sum()) * d * prob.n_rects
    wide = dataclasses.replace(prob, net_bits=prob.net_bits * 2)
    repl = dataclasses.replace(prob, n_rects=prob.n_rects * 3)
    assert pipelining.registers_at_depth(wide, d) == 2 * base
    assert pipelining.registers_at_depth(repl, d) == 3 * base
    assert pipelining.registers_at_depth(prob, 2 * d) == 2 * base
    # per-net (auto) pipelining bills the same way
    g = G.random_genotype(KEY, prob)
    r1 = pipelining.auto_pipeline(prob, g, 500.0)
    assert (pipelining.auto_pipeline(wide, g, 500.0).total_registers
            == 2 * r1.total_registers)
    assert (pipelining.auto_pipeline(repl, g, 500.0).total_registers
            == 3 * r1.total_registers)


def test_better_placement_needs_fewer_registers():
    """The paper's register-savings mechanism: smaller wirelength =>
    fewer pipelining registers at the same target frequency."""
    prob = netlist.make_problem(device.get_device("xcvu_test"))
    state, _ = evolve.run(prob, "nsga2", NSGA2Config(pop_size=16), KEY, 30)
    g_opt = jax.tree.map(lambda a: a[0], state["pop"])
    g_rand = G.random_genotype(jax.random.PRNGKey(77), prob)
    r_opt = pipelining.auto_pipeline(prob, g_opt, 500.0)
    r_rand = pipelining.auto_pipeline(prob, g_rand, 500.0)
    assert r_opt.total_registers <= r_rand.total_registers
