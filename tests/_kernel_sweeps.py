"""Shared shape/dtype sweep machinery for the differential kernel tests.

Every Pallas kernel here is validated the same way: synthesise inputs for
a grid of shapes chosen to cross the TPU tile boundaries (8-sublane
population tiles, 128/512-lane net and unit tiles), run the kernel in
interpret mode, and compare against the `ref.py` oracle.  This module
centralises the shape grids and input synthesis so `test_fused_eval.py`
and any future kernel test sweep the same contract.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

# Population sizes crossing the 8-sublane tile edge (7/8/9), the 128-lane
# domination tile edge (127/128/129 via the dom sweep), and a non-trivial
# interior point.
POP_SIZES = (1, 7, 8, 9, 13)

# (gids, nets, units, blocks) crossing the BN=512 net tile edge, the
# BU=128 unit tile edge, and odd extents forcing padding on every axis.
# units * blocks <= gids is NOT required: uidx entries just index gids.
EVAL_SHAPES = (
    (37, 11, 5, 7),        # tiny, everything padded
    (96, 511, 3, 28),      # one net short of a full tile
    (96, 512, 3, 28),      # exactly one net tile
    (96, 513, 3, 28),      # one net over
    (640, 40, 127, 5),     # one unit short of a tile
    (640, 40, 128, 5),     # exactly one unit tile
    (640, 40, 129, 5),     # one unit over
    (3640, 999, 130, 28),  # realistic decode extents, both axes ragged
)

DOM_SIZES = (3, 64, 127, 128, 129, 200)

DTYPES = (jnp.float32, jnp.bfloat16)


class EvalCase(NamedTuple):
    cx: jnp.ndarray     # [P, G]
    cy: jnp.ndarray
    src: jnp.ndarray    # [N] int32
    dst: jnp.ndarray
    w: jnp.ndarray      # [N]
    uidx: jnp.ndarray   # [U, B] int32


def make_eval_case(p: int, g: int, n: int, u: int, b: int,
                   dtype=jnp.float32, seed: int = 0) -> EvalCase:
    """Random fused-eval inputs at the given extents."""
    ks = jax.random.split(jax.random.PRNGKey(seed * 7919 + p * 131 + n), 6)
    cx = (jax.random.normal(ks[0], (p, g), jnp.float32) * 50).astype(dtype)
    cy = (jax.random.normal(ks[1], (p, g), jnp.float32) * 50).astype(dtype)
    src = jax.random.randint(ks[2], (n,), 0, g, jnp.int32)
    dst = jax.random.randint(ks[3], (n,), 0, g, jnp.int32)
    w = (jnp.abs(jax.random.normal(ks[4], (n,), jnp.float32)) * 0.1
         ).astype(dtype)
    uidx = jax.random.randint(ks[5], (u, b), 0, g, jnp.int32)
    return EvalCase(cx, cy, src, dst, w, uidx)


def make_dom_case(p: int, seed: int = 0) -> jnp.ndarray:
    """[P, 2] objectives with planted duplicates + exact ties (the
    strict/non-strict domination edges)."""
    objs = jax.random.uniform(jax.random.PRNGKey(seed * 31 + p), (p, 2))
    if p >= 2:
        objs = objs.at[1].set(objs[0])          # full duplicate row
    if p >= 4:
        objs = objs.at[3, 0].set(objs[2, 0])    # tie on one objective only
    return objs


def tol(dtype) -> dict:
    """assert_allclose kwargs per input dtype (fp32 accumulation in both
    paths; bf16 inputs lose mantissa before the accumulate)."""
    if dtype == jnp.bfloat16:
        return dict(rtol=2e-2, atol=2e-2)
    return dict(rtol=1e-5, atol=1e-6)
