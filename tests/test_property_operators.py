"""Property tests: variation operators keep genotypes in the legal space.

Runs with hypothesis when installed, else the deterministic fallback
sampler (`_hypothesis_compat`) -- either way these execute from a bare
environment.  The load-bearing property is the paper's SS III-A.3 claim:
*every* genotype the operators can produce decodes to a legal placement,
so the search never needs a repair/legalisation pass.
"""
import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core import genotype as G
from repro.core import nsga2, objectives as O
from repro.fpga import device, netlist

PROB = netlist.make_problem(device.get_device("xcvu_test"))


def _is_perm(x, n: int) -> bool:
    return np.array_equal(np.sort(np.asarray(x)), np.arange(n))


@settings(max_examples=40, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), n=st.integers(2, 80))
def test_ox_always_returns_permutation(seed, n):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    p1 = jax.random.permutation(k1, n).astype(jnp.int32)
    p2 = jax.random.permutation(k2, n).astype(jnp.int32)
    assert _is_perm(nsga2._ox(k3, p1, p2), n)


@settings(max_examples=40, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), n=st.integers(2, 80),
       swaps=st.integers(1, 6))
def test_swap_mut_always_returns_permutation(seed, n, swaps):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    p = jax.random.permutation(k1, n).astype(jnp.int32)
    assert _is_perm(nsga2._swap_mut(k2, p, swaps, 0.7), n)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_random_genotypes_decode_legal(seed):
    g = G.random_genotype(jax.random.PRNGKey(seed), PROB)
    O.assert_valid(PROB, g)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_varied_children_decode_legal(seed):
    """Children of random parents pass the independent placement checker."""
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    g1 = G.random_genotype(k1, PROB)
    g2 = G.random_genotype(k2, PROB)
    child = nsga2._vary_one(k3, g1, g2, nsga2.NSGA2Config(pop_size=4))
    O.assert_valid(PROB, child)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_reduced_children_decode_legal(seed):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    p1 = tuple(G.random_genotype(k1, PROB)["perm"])
    p2 = tuple(G.random_genotype(k2, PROB)["perm"])
    child = nsga2._vary_one_reduced(
        k3, p1, p2, nsga2.NSGA2Config(pop_size=4, reduced=True))
    O.assert_valid(PROB, G.reduced_to_full(PROB, child))
