"""Differential harness for the fused evaluation pipeline.

Four layers of defence, mirroring how the kernel can fail:

  1. interpret-vs-ref shape/dtype sweeps (`pallas_interpret`): the tiled
     Pallas body, executed on CPU, must match `ref.fused_eval_ref` across
     extents crossing every tile boundary (tests/_kernel_sweeps.py).
  2. padding-contract unit tests: the `kernels._padding` helpers (re-
     exported by `ops`) must produce padding that is *neutral under the
     fused reduction* -- planted worst-case values in the only cells the
     padding can reference must not leak into results.
  3. property tests (hypothesis when installed, deterministic fallback
     otherwise): permutation-invariance of domination rank, translation-
     invariance of bbox, exact quadratic scaling of wirelength^2 in the
     net weights.
  4. dispatch equivalence: on CPU `ops.fused_eval` (ref oracle) must be
     bitwise identical to the unfused two-op dispatch.
"""
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

sys.path.insert(0, str(Path(__file__).parent))
from _hypothesis_compat import given, settings, st  # noqa: E402
from _kernel_sweeps import (DOM_SIZES, DTYPES, EVAL_SHAPES,  # noqa: E402
                            POP_SIZES, make_dom_case, make_eval_case, tol)

from repro.kernels import fused_eval as FE  # noqa: E402
from repro.kernels import ops, ref  # noqa: E402

# ------------------------------------------------- interpret-vs-ref sweeps


@pytest.mark.pallas_interpret
@pytest.mark.parametrize("g,n,u,b", EVAL_SHAPES)
def test_fused_eval_shapes_match_ref(g, n, u, b):
    c = make_eval_case(5, g, n, u, b)
    got = FE.fused_eval_pallas(*c, interpret=True)
    want = ref.fused_eval_ref(*c)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               **tol(jnp.float32))


@pytest.mark.pallas_interpret
@pytest.mark.parametrize("p", POP_SIZES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_fused_eval_pop_tiles_and_dtypes(p, dtype):
    c = make_eval_case(p, 96, 200, 37, 11, dtype=dtype, seed=p)
    got = FE.fused_eval_pallas(*c, interpret=True)
    want = ref.fused_eval_ref(*c)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **tol(dtype))


@pytest.mark.pallas_interpret
def test_fused_eval_batched_axes_flatten():
    """Leading (slots, islands) axes flatten into the population grid."""
    c = make_eval_case(12, 96, 50, 9, 7)
    cx = c.cx.reshape(2, 2, 3, -1)
    cy = c.cy.reshape(2, 2, 3, -1)
    got = FE.fused_eval_pallas(cx, cy, c.src, c.dst, c.w, c.uidx,
                               interpret=True)
    assert got.shape == (2, 2, 3, 2)
    flat = FE.fused_eval_pallas(*c, interpret=True)
    np.testing.assert_array_equal(np.asarray(got).reshape(12, 2),
                                  np.asarray(flat))


@pytest.mark.pallas_interpret
@pytest.mark.parametrize("p", DOM_SIZES)
def test_domination_counts_match_ref(p):
    objs = make_dom_case(p)
    dom, cnt = FE.domination_counts_pallas(objs, interpret=True)
    want = ref.domination_ref(objs)
    np.testing.assert_array_equal(np.asarray(dom.astype(bool)),
                                  np.asarray(want))
    np.testing.assert_array_equal(
        np.asarray(cnt), np.asarray(want).astype(np.int32).sum(axis=0))


# --------------------------------------------------- padding contracts


def test_pad_net_indices_weights_are_zero():
    src = jnp.arange(11, dtype=jnp.int32)
    dst = jnp.arange(11, dtype=jnp.int32)[::-1]
    w = jnp.ones(11)
    ps, pd, pw = ops.pad_net_indices(src, dst, w, 512, n_tiles=3)
    assert ps.shape == (1536,)
    np.testing.assert_array_equal(np.asarray(pw[11:]), 0.0)
    # indices stay in range of any gid table (they pad with 0)
    assert int(jnp.max(ps)) <= 10 and int(jnp.min(ps)) >= 0


def test_pad_unit_index_rows_are_gid_zero():
    uidx = jnp.arange(5 * 7, dtype=jnp.int32).reshape(5, 7) + 3
    p = ops.pad_unit_index(uidx, 128, bb=8, n_tiles=2)
    assert p.shape == (256, 8)
    # padded blocks replicate each unit's last block (edge padding)
    np.testing.assert_array_equal(np.asarray(p[:5, 7]),
                                  np.asarray(uidx[:, -1]))
    # padded unit rows are all gid 0 -> degenerate unit, bbox exactly 0
    np.testing.assert_array_equal(np.asarray(p[5:]), 0)


def test_padded_nets_neutral_worst_case():
    """Plant the worst case the net padding can reference: gid 0 sits at
    an extreme coordinate.  Padded nets gather gid 0 with w == 0, so the
    fused result must equal the ref on the unpadded inputs."""
    c = make_eval_case(4, 96, 513, 9, 7)          # 513 nets: one over a tile
    cx = c.cx.at[:, 0].set(1e9)
    cy = c.cy.at[:, 0].set(-1e9)
    got = FE.fused_eval_pallas(cx, cy, c.src, c.dst, c.w, c.uidx,
                               interpret=True)
    want = ref.fused_eval_ref(cx, cy, c.src, c.dst, c.w, c.uidx)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5)


def test_padded_units_neutral_worst_case():
    """129 units forces a padded unit tile whose rows gather gid 0; with
    gid 0 planted at an extreme coordinate the degenerate unit's bbox is
    still exactly 0 and must not move the max."""
    c = make_eval_case(4, 640, 40, 129, 5)
    cx = c.cx.at[:, 0].set(3.0e37)
    cy = c.cy.at[:, 0].set(-3.0e37)
    got = FE.fused_eval_pallas(cx, cy, c.src, c.dst, c.w, c.uidx,
                               interpret=True)
    want = ref.fused_eval_ref(cx, cy, c.src, c.dst, c.w, c.uidx)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5)


def test_pad_unit_blocks_neutral_under_ref():
    """bbox layout: replicate-padding blocks and units never moves the
    min/max reduction."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    ux = jax.random.normal(k1, (3, 5, 13)) * 50      # [P, B, U] layout
    uy = jax.random.normal(k2, (3, 5, 13)) * 50
    px, py = ops.pad_unit_blocks(ux, uy, 8, 128)
    assert px.shape == (3, 8, 128)
    got = ref.maxbbox_ref(jnp.swapaxes(px, 1, 2), jnp.swapaxes(py, 1, 2))
    want = ref.maxbbox_ref(jnp.swapaxes(ux, 1, 2), jnp.swapaxes(uy, 1, 2))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


def test_pad_objs_inf_rows_dominate_nothing():
    objs = make_dom_case(9)
    padded = ops.pad_objs_inf(objs, 128)
    assert padded.shape == (128, 2)
    dom = np.asarray(ref.domination_ref(padded))
    # padded rows (>= 9) dominate nothing: their count contribution is 0
    assert not dom[9:, :].any()
    np.testing.assert_array_equal(
        dom[:9, :9], np.asarray(ref.domination_ref(objs)))


def test_pad_multiple_modes():
    a = jnp.asarray([[1.0, 2.0], [3.0, 4.0]])
    z = ops.pad_multiple(a, 1, 4, mode="zero")
    e = ops.pad_multiple(a, 1, 4, mode="edge")
    np.testing.assert_array_equal(np.asarray(z[:, 2:]), 0.0)
    np.testing.assert_array_equal(np.asarray(e[:, 2:]),
                                  [[2.0, 2.0], [4.0, 4.0]])
    assert ops.pad_multiple(a, 0, 2).shape == (2, 2)   # already aligned


# ------------------------------------------------------ property tests


@settings(max_examples=15)
@given(seed=st.integers(min_value=0, max_value=10_000),
       p=st.integers(min_value=2, max_value=40))
def test_domination_rank_permutation_invariant(seed, p):
    """Relabeling candidates permutes their Pareto front indices."""
    from repro.core.nsga2 import nondominated_rank
    objs = make_dom_case(p, seed=seed)
    perm = jax.random.permutation(jax.random.PRNGKey(seed + 1), p)
    r = np.asarray(nondominated_rank(objs, fused=True))
    rp = np.asarray(nondominated_rank(objs[perm], fused=True))
    np.testing.assert_array_equal(r[np.asarray(perm)], rp)


@settings(max_examples=15)
@given(seed=st.integers(min_value=0, max_value=10_000),
       shift=st.integers(min_value=-500, max_value=500))
def test_bbox_translation_invariant(seed, shift):
    """Translating every block moves no bbox width/height."""
    c = make_eval_case(3, 96, 20, 9, 7, seed=seed)
    base = FE.fused_eval_pallas(*c, interpret=True)
    moved = FE.fused_eval_pallas(c.cx + shift, c.cy - shift, c.src, c.dst,
                                 c.w, c.uidx, interpret=True)
    np.testing.assert_allclose(np.asarray(moved[..., 1]),
                               np.asarray(base[..., 1]), rtol=1e-5,
                               atol=1e-3)


@settings(max_examples=15)
@given(seed=st.integers(min_value=0, max_value=10_000),
       scale=st.integers(min_value=1, max_value=8))
def test_wirelength_quadratic_in_weights(seed, scale):
    """wl2(s * w) == s^2 * wl2(w): Eq. 1 is quadratic in the net weights,
    so scaling weights up can never decrease it (monotonicity)."""
    c = make_eval_case(3, 96, 200, 9, 7, seed=seed)
    base = FE.fused_eval_pallas(*c, interpret=True)[..., 0]
    scaled = FE.fused_eval_pallas(c.cx, c.cy, c.src, c.dst,
                                  c.w * float(scale), c.uidx,
                                  interpret=True)[..., 0]
    np.testing.assert_allclose(np.asarray(scaled),
                               float(scale) ** 2 * np.asarray(base),
                               rtol=1e-4)
    assert (np.asarray(scaled) >= np.asarray(base) - 1e-6).all()


# --------------------------------------------------- dispatch equivalence


def test_ops_fused_eval_bitwise_matches_unfused_dispatch(monkeypatch):
    """On the CPU ref path, the fused dispatch is composed from the same
    oracles as the two-op dispatch -- bitwise identical."""
    monkeypatch.delenv("REPRO_PALLAS", raising=False)
    c = make_eval_case(6, 96, 200, 37, 11)
    fused = ops.fused_eval(*c)
    wl = ops.wirelength2(c.cx[:, c.src], c.cy[:, c.src],
                         c.cx[:, c.dst], c.cy[:, c.dst], c.w)
    bb = ops.maxbbox(c.cx[:, c.uidx], c.cy[:, c.uidx])
    np.testing.assert_array_equal(np.asarray(fused[..., 0]), np.asarray(wl))
    np.testing.assert_array_equal(np.asarray(fused[..., 1]), np.asarray(bb))


def test_ops_fused_domination_counts_matches_matrix(monkeypatch):
    monkeypatch.delenv("REPRO_PALLAS", raising=False)
    objs = make_dom_case(50, seed=3)
    dom, cnt = ops.fused_domination_counts(objs)
    want = ops.domination_matrix(objs)
    np.testing.assert_array_equal(np.asarray(dom), np.asarray(want))
    np.testing.assert_array_equal(
        np.asarray(cnt), np.asarray(want).astype(np.int32).sum(axis=0))
