"""Per-kernel allclose sweeps: Pallas (interpret mode) vs ref.py oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import bbox, domination, flash_attention, ref, wirelength


def _rand(key, shape, dtype):
    x = jax.random.normal(key, shape, jnp.float32) * 50.0
    return x.astype(dtype)


# ------------------------------------------------------------ wirelength

@pytest.mark.parametrize("p,n", [(1, 7), (3, 512), (8, 1999), (13, 4097)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_wirelength_matches_ref(p, n, dtype):
    ks = jax.random.split(jax.random.PRNGKey(p * 1000 + n), 5)
    args = [_rand(k, (p, n), dtype) for k in ks[:4]]
    w = jnp.abs(_rand(ks[4], (p, n), dtype)) * 0.1
    got = wirelength.wirelength2_pallas(*args, w, interpret=True)
    want = ref.wirelength2_ref(*args, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-2 if dtype == jnp.bfloat16 else 1e-5)


# ------------------------------------------------------------------ bbox

@pytest.mark.parametrize("p,u,b", [(1, 6, 28), (4, 80, 28), (2, 130, 5),
                                   (3, 128, 32)])
@pytest.mark.parametrize("dtype", [jnp.float32])
def test_maxbbox_matches_ref(p, u, b, dtype):
    k1, k2 = jax.random.split(jax.random.PRNGKey(p + u + b))
    ux = _rand(k1, (p, u, b), dtype)
    uy = _rand(k2, (p, u, b), dtype)
    got = bbox.maxbbox_pallas(ux, uy, interpret=True)
    want = ref.maxbbox_ref(ux, uy)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5)


# ------------------------------------------------------------ domination

@pytest.mark.parametrize("p", [3, 64, 127, 200])
def test_domination_matches_ref(p):
    objs = jax.random.uniform(jax.random.PRNGKey(p), (p, 2))
    # inject duplicates + exact ties to hit the strict/non-strict edges
    objs = objs.at[1].set(objs[0])
    got = domination.domination_pallas(objs, interpret=True).astype(bool)
    want = ref.domination_ref(objs)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_domination_irreflexive_antisymmetric():
    objs = jax.random.uniform(jax.random.PRNGKey(0), (50, 2))
    d = np.asarray(domination.domination_pallas(objs, interpret=True))
    assert not d.diagonal().any()
    assert not (d & d.T).any()


# -------------------------------------------------------- flash attention

@pytest.mark.parametrize("b,h,hkv,s,d", [
    (1, 2, 2, 128, 64),     # MHA, exact blocks
    (2, 4, 2, 200, 64),     # GQA, ragged seq
    (1, 8, 1, 384, 128),    # MQA
    (1, 2, 2, 96, 64),      # sub-block seq
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_causal(b, h, hkv, s, d, dtype):
    ks = jax.random.split(jax.random.PRNGKey(h * s), 3)
    q = _rand(ks[0], (b, h, s, d), dtype) * 0.02
    k = _rand(ks[1], (b, hkv, s, d), dtype) * 0.02
    v = _rand(ks[2], (b, hkv, s, d), dtype) * 0.02
    got = flash_attention.flash_attention_pallas(q, k, v, causal=True,
                                                 interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=2e-2 if dtype == jnp.bfloat16 else 2e-5,
        atol=2e-2 if dtype == jnp.bfloat16 else 1e-5)


@pytest.mark.parametrize("window", [32, 128])
def test_flash_attention_sliding_window(window):
    ks = jax.random.split(jax.random.PRNGKey(window), 3)
    q = _rand(ks[0], (1, 2, 256, 64), jnp.float32) * 0.02
    k = _rand(ks[1], (1, 2, 256, 64), jnp.float32) * 0.02
    v = _rand(ks[2], (1, 2, 256, 64), jnp.float32) * 0.02
    got = flash_attention.flash_attention_pallas(
        q, k, v, causal=True, window=window, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=1e-5)


def test_flash_attention_decode_chunk():
    """S < T: queries are the last S positions (chunked decode/prefill)."""
    ks = jax.random.split(jax.random.PRNGKey(9), 3)
    q = _rand(ks[0], (2, 4, 64, 64), jnp.float32) * 0.02
    k = _rand(ks[1], (2, 2, 320, 64), jnp.float32) * 0.02
    v = _rand(ks[2], (2, 2, 320, 64), jnp.float32) * 0.02
    got = flash_attention.flash_attention_pallas(q, k, v, causal=True,
                                                 interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=1e-5)


def test_ops_flash_attention_grad_runs():
    """custom_vjp backward (ref recompute) produces finite grads."""
    from repro.kernels import ops
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = _rand(ks[0], (1, 2, 32, 16), jnp.float32) * 0.05
    k = _rand(ks[1], (1, 2, 32, 16), jnp.float32) * 0.05
    v = _rand(ks[2], (1, 2, 32, 16), jnp.float32) * 0.05

    def loss(q, k, v):
        return jnp.sum(ops.flash_attention(q, k, v, True, None, None) ** 2)

    g = jax.grad(loss)(q, k, v)
    assert np.isfinite(np.asarray(g)).all()


def test_decode_attention_ref_masks_correctly():
    ks = jax.random.split(jax.random.PRNGKey(4), 3)
    q = _rand(ks[0], (2, 4, 32), jnp.float32) * 0.05
    kc = _rand(ks[1], (2, 2, 64, 32), jnp.float32) * 0.05
    vc = _rand(ks[2], (2, 2, 64, 32), jnp.float32) * 0.05
    out_full = ref.decode_attention_ref(q, kc, vc, jnp.asarray([64, 64]))
    # truncated cache must equal full compute on the truncated arrays
    out_trunc = ref.decode_attention_ref(q, kc, vc, jnp.asarray([40, 64]))
    want40 = ref.decode_attention_ref(
        q[:1], kc[:1, :, :40], vc[:1, :, :40], jnp.asarray([40]))
    np.testing.assert_allclose(np.asarray(out_trunc[0]),
                               np.asarray(want40[0]), rtol=1e-5, atol=1e-6)
    assert not np.allclose(np.asarray(out_full[0]), np.asarray(out_trunc[0]))
