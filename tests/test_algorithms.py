"""Algorithm correctness: NSGA-II machinery vs oracles + optimization sanity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import annealing, cmaes, evolve, ga, nsga2, objectives as O
from repro.fpga import device, netlist

PROB = netlist.make_problem(device.get_device("xcvu_test"))
KEY = jax.random.PRNGKey(0)


# --------------------------------------------------- NSGA-II machinery

def _rank_oracle(objs: np.ndarray) -> np.ndarray:
    """O(P^2 M) peel-off non-dominated sorting oracle."""
    p = objs.shape[0]
    rank = np.full(p, -1)
    alive = np.ones(p, bool)
    r = 0
    while alive.any():
        front = []
        for i in np.where(alive)[0]:
            dominated = False
            for j in np.where(alive)[0]:
                if i == j:
                    continue
                if np.all(objs[j] <= objs[i]) and np.any(objs[j] < objs[i]):
                    dominated = True
                    break
            if not dominated:
                front.append(i)
        for i in front:
            rank[i] = r
            alive[i] = False
        r += 1
    return rank


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), p=st.integers(4, 40))
def test_nondominated_rank_matches_oracle(seed, p):
    objs = jax.random.uniform(jax.random.PRNGKey(seed), (p, 2))
    got = np.asarray(nsga2.nondominated_rank(objs))
    want = _rank_oracle(np.asarray(objs))
    np.testing.assert_array_equal(got, want)


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), n=st.integers(3, 64))
def test_ox_crossover_emits_permutations(seed, n):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    p1 = jax.random.permutation(k1, n).astype(jnp.int32)
    p2 = jax.random.permutation(k2, n).astype(jnp.int32)
    child = nsga2._ox(k3, p1, p2)
    np.testing.assert_array_equal(np.sort(np.asarray(child)), np.arange(n))


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_swap_mutation_emits_permutations(seed):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    p = jax.random.permutation(k1, 33).astype(jnp.int32)
    out = nsga2._swap_mut(k2, p, 3, 0.7)
    np.testing.assert_array_equal(np.sort(np.asarray(out)), np.arange(33))


def test_ox_preserves_segment():
    # with deterministic parents, child must contain p1's values
    p1 = jnp.arange(10, dtype=jnp.int32)
    p2 = jnp.asarray(list(reversed(range(10))), jnp.int32)
    child = nsga2._ox(jax.random.PRNGKey(5), p1, p2)
    np.testing.assert_array_equal(np.sort(np.asarray(child)), np.arange(10))


def test_crowding_boundaries_are_infinite():
    objs = jnp.asarray([[0., 3.], [1., 2.], [2., 1.], [3., 0.]])
    rank = nsga2.nondominated_rank(objs)
    crowd = nsga2.crowding_distance(objs, rank)
    c = np.asarray(crowd)
    assert c[0] >= 1e9 and c[3] >= 1e9   # extremes of the single front
    assert c[1] < 1e9 and c[2] < 1e9


# ----------------------------------------------------- optimization runs

def _improves(hist) -> bool:
    c = np.asarray(O.combined_metric(hist))
    return c[-1] < c[0]


def test_nsga2_improves():
    _, hist = evolve.run(PROB, "nsga2", nsga2.NSGA2Config(pop_size=16),
                         KEY, 25)
    assert _improves(hist)


def test_nsga2_reduced_improves():
    cfg = nsga2.NSGA2Config(pop_size=16, reduced=True)
    _, hist = evolve.run(PROB, "nsga2", cfg, KEY, 25)
    assert _improves(hist)


def test_cmaes_improves():
    _, hist = evolve.run(PROB, "cmaes", cmaes.CMAESConfig(pop_size=12),
                         KEY, 40)
    assert _improves(hist)


def test_sa_improves():
    cfg = annealing.SAConfig(schedule="hyperbolic")
    st0 = annealing.init_state(PROB, KEY, cfg)
    out = annealing.run_chain(PROB, cfg, KEY, 400, st0)
    first = O.combined_metric(out["history"][0])
    last = O.combined_metric(out["state"]["best_objs"])
    assert float(last) < float(first)


def test_ga_improves():
    _, hist = evolve.run(PROB, "ga", ga.GAConfig(pop_size=16), KEY, 25)
    assert _improves(hist)


@pytest.mark.parametrize("schedule", annealing.SCHEDULES)
def test_sa_schedules_run(schedule):
    cfg = annealing.SAConfig(schedule=schedule)
    st0 = annealing.init_state(PROB, KEY, cfg)
    out = annealing.run_chain(PROB, cfg, KEY, 50, st0)
    assert np.isfinite(np.asarray(out["state"]["best_objs"])).all()


def test_nsga2_children_always_legal():
    cfg = nsga2.NSGA2Config(pop_size=8)
    state = nsga2.init_state(PROB, KEY, cfg)
    for i in range(3):
        state = nsga2.step(PROB, cfg, state, jax.random.fold_in(KEY, i))
    for j in range(8):
        O.assert_valid(PROB, jax.tree.map(lambda a: a[j], state["pop"]))


def test_islands_migration_improves():
    st, hist = evolve.run_islands(
        PROB, "nsga2", nsga2.NSGA2Config(pop_size=8), KEY,
        rounds=3, gens_per_round=4)
    c = np.asarray(O.combined_metric(hist))
    assert c[-1].min() <= c[0].min()


def test_cmaes_best_genotype_valid():
    cfg = cmaes.CMAESConfig(pop_size=8)
    state, _ = evolve.run(PROB, "cmaes", cfg, KEY, 10)
    g, objs = cmaes.best_genotype(PROB, state)
    O.assert_valid(PROB, g)
    assert np.isfinite(np.asarray(objs)).all()
