"""Observability layer: metrics registry, tracing, exporters, frontend ETA.

Covers the observability PR's acceptance contracts:
  * `runtime.telemetry`: counter/gauge/histogram semantics, instrument
    memoization (same name -> same instance, kind mismatch raises),
    Prometheus text exposition (cumulative buckets, `+Inf` == `_count`,
    label escaping) and the stdlib HTTP exporter,
  * `serve.tracing`: span/instant recording, the enable/disable switch,
    Chrome-trace export, and the JSONL sink,
  * reconciliation under load: 32 concurrent clients through the async
    front-end with tracing on -- every submitted job emits exactly one
    `job.submit` and exactly one terminal event, cancelled jobs emit
    `job.cancelled` and never `job.harvested`, and the event counts
    reconcile EXACTLY with the layered `stats()` counters,
  * traces survive `drain()` / `aclose()`: `JobHandle.trace()` still
    returns the span tree and convergence history after the front-end is
    gone,
  * the frontend ETA regression (`_extrapolate_eta`): never negative,
    `None` at ~zero elapsed / zero gens / non-finite metric.

Tracing is process-global state: every test that enables it restores the
prior state in a finally (the suite must leave tracing off for the
purity-sensitive tests around it).

No pytest-asyncio in the toolchain: async scenarios run under
`asyncio.run()` inside synchronous tests.
"""
import asyncio
import json
import urllib.request

import pytest

from repro.core import nsga2
from repro.runtime import telemetry
from repro.serve import tracing
from repro.serve.api import JobCancelledError, JobRequest, stats_payload
from repro.serve.frontend import PlacementFrontend, _extrapolate_eta
from repro.serve.scheduler import PlacementScheduler

CFG = nsga2.NSGA2Config(pop_size=8)


def _req(seed: int, budget: int = 4, **kw) -> JobRequest:
    return JobRequest(device="xcvu_test", cfg=CFG, seed=seed,
                      budget=budget, **kw)


# --------------------------------------------------- metrics registry

def test_counter_gauge_histogram_semantics():
    reg = telemetry.MetricsRegistry()
    c = reg.counter("t_jobs_total", "jobs")
    c.inc()
    c.inc(2, device="a")
    assert c.value() == 1 and c.value(device="a") == 2
    with pytest.raises(ValueError):
        c.inc(-1)
    g = reg.gauge("t_depth", "queue depth")
    g.set(5)
    g.inc(2)
    g.dec()
    assert g.value() == 6
    h = reg.histogram("t_lat_ms", "latency", buckets=(1, 10, 100))
    for v in (0.5, 5, 50, 500):
        h.observe(v)
    d = h.to_dict()
    assert d["count"] == 4 and d["sum"] == 555.5
    assert d["counts"] == [1, 1, 1] and d["overflow"] == 1


def test_registry_memoizes_and_rejects_kind_mismatch():
    reg = telemetry.MetricsRegistry()
    a = reg.counter("t_same", "x")
    assert reg.counter("t_same", "x") is a
    with pytest.raises(TypeError):
        reg.gauge("t_same", "x")
    with pytest.raises(ValueError):
        reg.histogram("t_bad", "x", buckets=(10, 1))   # not ascending


def test_prometheus_text_exposition_well_formed():
    reg = telemetry.MetricsRegistry()
    reg.counter("t_ops_total", "ops").inc(3, kind='we"ird\\')
    h = reg.histogram("t_ms", "ms", buckets=(1, 10))
    h.observe(5, layer="fe")
    h.observe(50, layer="fe")
    text = reg.prometheus_text()
    assert "# TYPE t_ops_total counter" in text
    assert "# HELP t_ms ms" in text
    # label escaping: backslash and quote escaped in the exposition
    assert 'kind="we\\"ird\\\\"' in text
    # cumulative buckets; +Inf bucket equals _count
    assert 't_ms_bucket{layer="fe",le="1"} 0' in text
    assert 't_ms_bucket{layer="fe",le="10"} 1' in text
    assert 't_ms_bucket{layer="fe",le="+Inf"} 2' in text
    assert 't_ms_count{layer="fe"} 2' in text


def test_http_exporter_serves_scrape():
    reg = telemetry.MetricsRegistry()
    reg.counter("t_scrape_total", "scrapes").inc(7)
    server, port = telemetry.start_http_server(0, reg)
    try:
        url = f"http://127.0.0.1:{port}/metrics"
        with urllib.request.urlopen(url, timeout=10) as r:
            assert r.status == 200
            assert "version=0.0.4" in r.headers["Content-Type"]
            body = r.read().decode("utf-8")
        assert "t_scrape_total 7" in body
    finally:
        server.shutdown()


def test_compile_meter_rows_in_global_registry():
    text = telemetry.registry().prometheus_text()
    assert "repro_compiles_total" in text
    assert "repro_compile_cache_hits_total" in text


def test_stats_payload_stamps_schema_version():
    s = stats_payload(a=1, b=2)
    assert list(s)[0] == "schema_version"
    assert s["a"] == 1 and s["b"] == 2


# --------------------------------------------------------- tracing core

def test_tracer_spans_instants_and_chrome_export(tmp_path):
    was = tracing.enabled()
    tracing.enable()
    t = tracing.tracer()
    t.clear()
    try:
        tid = tracing.new_trace_id()
        t.instant("job.submit", trace_id=tid, seed=1)
        with t.span("pool.step", active=3):
            t.instant("job.harvested", trace_id=tid, gens=4)
        evs = t.events(tid)
        assert [e.name for e in evs] == ["job.submit", "job.harvested"]
        assert evs[0].attrs["seed"] == 1
        pairs = tracing.span_pairs(t.events())
        assert [n for n, _ in pairs] == ["pool.step"]
        assert all(dt >= 0 for _, dt in pairs)
        out = tmp_path / "chrome.json"
        t.write_chrome_trace(str(out))
        doc = json.loads(out.read_text())
        phases = [e["ph"] for e in doc["traceEvents"]]
        assert phases.count("B") == phases.count("E") == 1
        assert phases.count("i") == 2
    finally:
        t.clear()
        if not was:
            tracing.disable(close_sinks=False)


def test_tracing_disabled_records_nothing():
    assert not tracing.enabled()           # suite invariant: default off
    before = len(tracing.tracer().events())
    tracing.tracer().instant("job.submit", trace_id="t-x")
    assert len(tracing.tracer().events()) == before


def test_jsonl_sink_appends_valid_lines(tmp_path):
    path = tmp_path / "trace.jsonl"
    was = tracing.enabled()
    tracing.enable(jsonl_path=str(path))
    try:
        tracing.tracer().instant("job.submit", trace_id="t-1", seed=9)
    finally:
        tracing.disable(close_sinks=True)
        if was:
            tracing.enable()
    (line,) = path.read_text().strip().splitlines()
    ev = json.loads(line)
    assert ev["name"] == "job.submit" and ev["trace"] == "t-1"
    assert ev["attrs"]["seed"] == 9


# ------------------------------------------------------ frontend ETA

def test_eta_never_negative_and_none_edge_cases():
    # steady progress: linear extrapolation of the remaining budget
    assert _extrapolate_eta(gens=4, budget=8, elapsed=2.0) == 2.0
    # overshoot (gens > budget after a final partial step) clamps to 0,
    # never a negative ETA
    assert _extrapolate_eta(gens=10, budget=8, elapsed=2.0) == 0.0
    # ~zero elapsed (first boundary lands inside the timer resolution)
    assert _extrapolate_eta(gens=4, budget=8, elapsed=0.0) is None
    assert _extrapolate_eta(gens=4, budget=8, elapsed=1e-9) is None
    # no generations served yet
    assert _extrapolate_eta(gens=0, budget=8, elapsed=2.0) is None
    # metric hasn't improved off its +inf init: no meaningful progress
    assert _extrapolate_eta(gens=4, budget=8, elapsed=2.0,
                            metric=float("inf")) is None


# -------------------------------------- reconciliation under 32 clients

def test_32_clients_events_reconcile_with_stats():
    n_clients, cancel_every = 32, 8
    was = tracing.enabled()
    tracing.enable()
    tracing.tracer().clear()

    async def client(fe, i):
        if (i + 1) % cancel_every == 0:
            # un-finishable budget: the cancel can never lose the race
            h = await fe.submit(_req(seed=i, budget=10_000))
            assert h.cancel() is True
            with pytest.raises(JobCancelledError):
                await h.wait()
            return h
        h = await fe.submit(_req(seed=i, budget=4))
        await h.wait()
        return h

    async def main():
        sched = PlacementScheduler(n_slots=8, gens_per_step=2)
        async with PlacementFrontend(sched, max_queue=16) as fe:
            handles = await asyncio.gather(
                *[client(fe, i) for i in range(n_clients)])
            stats = fe.stats()
        return handles, stats               # frontend now aclosed

    try:
        handles, stats = asyncio.run(main())
        n_cancelled = n_clients // cancel_every
        assert stats["submitted"] == n_clients
        assert stats["cancelled"] == n_cancelled
        assert stats["completed"] == n_clients - n_cancelled
        assert stats["failed"] == 0

        evs = tracing.tracer().events()
        by_name: dict = {}
        for e in evs:
            by_name.setdefault(e.name, []).append(e)
        # event counts reconcile EXACTLY with the stats() counters
        assert len(by_name["job.submit"]) == stats["submitted"]
        assert len(by_name["job.harvested"]) == stats["completed"]
        assert len(by_name["job.cancelled"]) == stats["cancelled"]
        assert "job.failed" not in by_name
        n_terminal = sum(len(by_name.get(n, []))
                         for n in tracing.TERMINAL_EVENTS)
        assert n_terminal == stats["submitted"]

        # per-trace exactly-once terminal; cancelled never harvested --
        # and the traces survived aclose()
        for h in handles:
            tr = h.trace()
            names = [e.name for e in tr.events]
            assert names.count("job.submit") == 1
            terminals = [n for n in names if n in tracing.TERMINAL_EVENTS]
            assert len(terminals) == 1
            if h.status.value == "cancelled":
                assert terminals == ["job.cancelled"]
                assert "job.harvested" not in names
            else:
                assert terminals == ["job.harvested"]
                # live convergence telemetry rode the progress stream
                assert tr.convergence
                gens = [g for g, _ in tr.convergence]
                assert gens == sorted(gens)
        # latency observed exactly once per job, on the frontend layer
        assert stats["job_latency_ms_hist"]["count"] == n_clients
        assert stats["tracing_enabled"] is True
    finally:
        tracing.tracer().clear()
        if not was:
            tracing.disable(close_sinks=False)


def test_traces_survive_drain():
    was = tracing.enabled()
    tracing.enable()
    tracing.tracer().clear()

    async def main():
        sched = PlacementScheduler(n_slots=2, gens_per_step=2)
        async with PlacementFrontend(sched, max_queue=8) as fe:
            handles = [await fe.submit(_req(seed=40 + i, budget=4))
                       for i in range(4)]
            await fe.drain()
            return handles

    try:
        handles = asyncio.run(main())
        for h in handles:
            tr = h.trace()
            assert tr.trace_id is not None
            names = [e.name for e in tr.events]
            assert names[0] == "job.submit"
            assert names[-1] == "job.harvested"
            # span_pairs-backed phase breakdown stays available too
            assert isinstance(tr.phases, list)
    finally:
        tracing.tracer().clear()
        if not was:
            tracing.disable(close_sinks=False)
