"""Device-model and netlist invariants, pinned to the paper's numbers."""
import numpy as np
import pytest

from repro.fpga import device, netlist
from repro.fpga.device import BRAM, DSP, URAM


def test_vu11p_matches_paper_utilization():
    dev = device.get_device("xcvu11p")
    util = dev.utilization()
    # paper SS III-C: 100% URAM, 93.7% DSP, 95.2% BRAM in the repeating rect
    assert util["URAM"] == pytest.approx(1.0)
    assert util["DSP"] == pytest.approx(0.9375, abs=1e-3)
    assert util["BRAM"] == pytest.approx(0.952, abs=1e-3)


def test_vu11p_full_chip_resources():
    dev = device.get_device("xcvu11p")
    # 6 rects x (5 cols x 32) URAM = 960; x (32 x 48) DSP = 9216;
    # x (14 x 48) RAMB18 = 4032  -- the paper's VU11P headline numbers
    tot = {t: int(np.sum(dev.columns[t].cap_sites)) * dev.n_rects
           for t in (URAM, DSP, BRAM)}
    assert tot[URAM] == 960
    assert tot[DSP] == 9216
    assert tot[BRAM] == 4032


@pytest.mark.parametrize("name,units", [
    ("xcvu3p", 123), ("xcvu5p", 246), ("xcvu7p", 246),
    ("xcvu9p", 369), ("xcvu11p", 480), ("xcvu13p", 640),
])
def test_design_sizes_match_table2(name, units):
    assert device.get_device(name).units_total == units


@pytest.mark.parametrize("name", device.list_devices())
def test_chain_capacity_sufficient(name):
    dev = device.get_device(name)
    for t in (URAM, DSP, BRAM):
        assert dev.chain_capacity(t) >= dev.chains_needed(t)


def test_netlist_structure(small_problem):
    p = small_problem
    # 28 blocks per unit; nets reference valid gids; weights positive
    assert p.n_blocks == p.n_units * netlist.BLOCKS_PER_UNIT
    assert p.net_src.max() < p.n_blocks and p.net_dst.max() < p.n_blocks
    assert (p.net_w > 0).all() and (p.net_bits > 0).all()
    # intra-unit nets stay within their unit except the systolic chain
    src_u = p.blk_unit[p.net_src]
    dst_u = p.blk_unit[p.net_dst]
    cross = np.sum(src_u != dst_u)
    assert cross == p.n_units - 1  # exactly the inter-unit URAM links


def test_register_model_in_paper_range():
    """Depth-1 pipelining of every net on VU11P should land in the paper's
    256K-323K register band (Table I)."""
    prob = netlist.make_problem(device.get_device("xcvu11p"))
    from repro.core import pipelining
    regs = pipelining.registers_at_depth(prob, 1)
    assert 230_000 <= regs <= 340_000, regs
