"""Compile-latency control: persistent cache, compile meter, AOT prewarm.

Covers the PR's acceptance contracts:
  * `runtime.compile_cache`: the meter counts real backend compiles and
    scopes them to the opening thread (`measure()`), `enable()` persists
    every compiled program to the cache directory, and a cleared
    in-memory jit cache re-loads from disk (cache hit, zero recompiles)
    -- the in-process version of the cross-process CI budget,
  * prewarm correctness: a scheduler pool adopted from the background
    prewarmer produces bitwise-identical job results to a cold-built
    pool, and a prewarm failure falls back to the synchronous build
    (latency, never jobs),
  * `grow()` on a prewarmed ladder size performs ZERO blocking compiles
    in the stepping loop (the same grow without prewarm must block on at
    least one),
  * champion-store traffic round-trip: `note_traffic` rows survive
    save/load and a FRESH store's `predicted_keys` drive
    `prewarm_predicted` end to end (restart -> prewarm -> adopt),
  * `PlacementScheduler._admit` resilience: an admission failure
    re-queues the job with an error note (transient failures recover,
    persistent ones surface as `failed` after bounded retries) and never
    wedges co-queued jobs or `run_all()`.
"""
import json
import threading

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import nsga2
from repro.fpga import device, netlist
from repro.runtime import compile_cache
from repro.serve.champion_store import ChampionStore
from repro.serve.placement_service import PlacementService
from repro.serve.prewarm import Prewarmer
from repro.serve.scheduler import PlacementScheduler

BASE = netlist.make_problem(device.get_device("xcvu_test"))
CFG = nsga2.NSGA2Config(pop_size=8)


def _drain(svc):
    done = []
    while svc.active.any():
        done.extend(svc.step())
    return done


# ---------------------------------------------------------- compile meter

def test_meter_counts_and_thread_scopes():
    m = compile_cache.meter().install()
    c = float(np.random.default_rng(0).standard_normal())  # unique consts

    with m.measure() as scope:
        jax.block_until_ready(jax.jit(lambda x: x * 2 + c)(jnp.ones(7)))
    assert scope.compiles >= 1
    assert scope.secs > 0

    # a compile on ANOTHER thread must not land in this thread's scope
    def other():
        jax.block_until_ready(jax.jit(lambda x: x * 3 + c)(jnp.ones(7)))

    with m.measure() as scope:
        t = threading.Thread(target=other)
        t.start()
        t.join()
    assert scope.compiles == 0
    assert m.compiles >= 2                    # but the global total saw it
    assert m.recompiles <= m.compiles


def test_persistent_cache_round_trip(tmp_path):
    """enable() -> compile -> clear in-memory caches -> reload from disk.

    `jax.clear_caches()` drops the in-process executable caches, so the
    second call can only avoid a real recompile by deserializing from the
    persistent directory -- the in-process mirror of the cross-process CI
    compile budget."""
    m = compile_cache.meter().install()
    d = str(tmp_path / "xc")
    try:
        assert compile_cache.enable(d) == d
        assert compile_cache.enabled_dir() == d

        c = float(np.random.default_rng(1).standard_normal())
        fn = jax.jit(lambda x: jnp.sin(x) * c)
        misses0, hits0 = m.cache_misses, m.cache_hits
        jax.block_until_ready(fn(jnp.ones(11)))
        assert m.cache_misses > misses0       # first compile: miss + write
        files = list(tmp_path.joinpath("xc").iterdir())
        assert files, "no entries persisted to the cache directory"

        jax.clear_caches()
        c0, r0, h0 = m.compiles, m.recompiles, m.cache_hits
        jax.block_until_ready(jax.jit(lambda x: jnp.sin(x) * c)(jnp.ones(11)))
        assert m.cache_hits > h0              # answered from disk...
        # ...so strictly fewer REAL compiles than compile requests (only
        # programs first compiled before enable() may recompile here)
        assert m.recompiles - r0 < m.compiles - c0
    finally:
        compile_cache.disable()
        assert compile_cache.enabled_dir() is None


def test_maybe_enable_from_env(tmp_path, monkeypatch):
    try:
        monkeypatch.delenv("REPRO_COMPILE_CACHE_DIR", raising=False)
        assert compile_cache.maybe_enable_from_env(None) is None
        d = str(tmp_path / "envxc")
        monkeypatch.setenv("REPRO_COMPILE_CACHE_DIR", d)
        assert compile_cache.maybe_enable_from_env(None) == d
        # an explicit flag beats the environment
        d2 = str(tmp_path / "flagxc")
        assert compile_cache.maybe_enable_from_env(d2) == d2
    finally:
        compile_cache.disable()


# ------------------------------------------------------- prewarm bitwise

def test_prewarmed_pool_results_bitwise_match_cold():
    spec = dict(seed=5, budget=4)
    warm_sch = PlacementScheduler(n_slots=2, gens_per_step=2, prewarm=True)
    warm_sch.prewarm("xcvu_test", CFG)
    assert warm_sch.prewarmer.wait_idle(timeout=300)
    assert warm_sch.prewarmer.builds_done == 1
    jid_w = warm_sch.submit("xcvu_test", CFG, **spec)
    warm = {j.jid: j for j in warm_sch.run_all()}[jid_w]
    assert warm_sch.prewarmer.adopted == 1    # took the background build

    cold_sch = PlacementScheduler(n_slots=2, gens_per_step=2)
    jid_c = cold_sch.submit("xcvu_test", CFG, **spec)
    cold = {j.jid: j for j in cold_sch.run_all()}[jid_c]

    assert warm.result.metric == cold.result.metric
    assert np.array_equal(warm.result.best_objs, cold.result.best_objs)
    for a, b in zip(jax.tree.leaves(warm.result.genotype),
                    jax.tree.leaves(cold.result.genotype)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_prewarm_failure_falls_back_to_synchronous_build():
    sch = PlacementScheduler(n_slots=2, gens_per_step=2, prewarm=True)
    key = sch.pool_key("xcvu_test", "nsga2", CFG)
    sch.prewarmer.prewarm_pool(key, lambda: 1 / 0)   # doomed build
    assert sch.prewarmer.wait_idle(timeout=60)
    assert sch.prewarmer.failures == 1
    assert sch.prewarmer.take(key) is None
    jid = sch.submit("xcvu_test", CFG, seed=0, budget=2)   # sync fallback
    done = {j.jid: j for j in sch.run_all()}
    assert done[jid].result is not None and done[jid].done
    assert "ZeroDivisionError" in json.dumps(sch.prewarmer.stats()["errors"])


def test_prewarmer_dedups_and_reports():
    pw = Prewarmer()
    built = []
    assert pw.prewarm_pool("k1", lambda: built.append(1) or "pool")
    assert not pw.prewarm_pool("k1", lambda: built.append(2) or "dup")
    assert pw.wait_idle(timeout=60)
    assert built == [1]
    assert pw.take("k1") == "pool"
    assert pw.take("k1") is None              # consumed
    s = pw.stats()
    assert s["builds_done"] == 1 and s["adopted"] == 1
    pw.stop()


# -------------------------------------------------- grow compile budget

def test_grow_on_prewarmed_size_zero_blocking_compiles():
    svc = PlacementService(BASE, CFG, n_slots=2, gens_per_step=2)
    svc.submit(seed=0, budget=64)
    svc.step()                                # all cold compiles done
    assert svc.blocking_compiles > 0

    assert svc.prewarm_size(4)
    assert not svc.prewarm_size(4)            # dedup
    assert not svc.prewarm_size(2)            # not a growth
    assert svc.prewarm_compiles > 0           # the ladder rung compiled...
    b0 = svc.blocking_compiles
    svc.grow(4)
    svc.step()
    svc.step()
    assert svc.blocking_compiles == b0        # ...so the loop never blocked
    assert 4 in svc.stats()["prewarmed_sizes"]

    # control: the same grow WITHOUT prewarm_size blocks on >= 1 compile
    ref = PlacementService(BASE, CFG, n_slots=2, gens_per_step=2)
    ref.submit(seed=0, budget=64)
    ref.step()
    b0 = ref.blocking_compiles
    ref.grow(4)
    ref.step()
    assert ref.blocking_compiles > b0


def test_grow_results_unchanged_by_prewarm():
    """prewarm_size moves compilation, never results: a grown pool's jobs
    match a pool that grew cold."""
    def run(prewarm: bool):
        svc = PlacementService(BASE, CFG, n_slots=1, gens_per_step=2)
        svc.submit(seed=7, budget=8)
        if prewarm:
            svc.prewarm_size(2)
        svc.grow(2)
        svc.submit(seed=8, budget=8)
        return {j.seed: j for j in _drain(svc)}

    a, b = run(True), run(False)
    assert a.keys() == b.keys()
    for seed in a:
        assert a[seed].metric == b[seed].metric
        assert np.array_equal(a[seed].best_objs, b[seed].best_objs)


# ------------------------------------------- store traffic -> prediction

def test_traffic_round_trip_drives_prewarm_predicted(tmp_path):
    store = ChampionStore()
    sch = PlacementScheduler(n_slots=2, gens_per_step=2, store=store)
    for s in range(2):                        # hottest signature: 2 hits
        sch.submit("xcvu_test", CFG, seed=s, budget=2)
    sch.submit("xcvu_test2", CFG, seed=0, budget=2)
    sch.run_all()

    path = str(tmp_path / "store.json")
    store.save(path)

    fresh = ChampionStore(path=path)          # the "restarted process"
    preds = fresh.predicted_keys()
    assert [p.count for p in preds] == [2, 1]
    assert preds[0].device_name == "xcvu_test"
    assert preds[0].algo == "nsga2" and preds[0].pop_size == 8

    sch2 = PlacementScheduler(n_slots=2, gens_per_step=2, store=fresh,
                              prewarm=True)
    keys = sch2.prewarm_predicted(top_k=1)
    assert len(keys) == 1
    assert sch2.prewarmer.wait_idle(timeout=300)
    assert sch2.prewarmer.builds_done == 1
    # traffic matching the prediction adopts the prewarmed pool -- note
    # the different float hyperparameters: only static fields route
    jid = sch2.submit("xcvu_test",
                      nsga2.NSGA2Config(pop_size=8, sbx_eta=19.0),
                      seed=3, budget=2)
    done = {j.jid: j for j in sch2.run_all()}
    assert done[jid].done
    assert sch2.prewarmer.adopted == 1


def test_traffic_counts_merge_on_load(tmp_path):
    a, b = ChampionStore(), ChampionStore()
    for store, n in ((a, 3), (b, 2)):
        for _ in range(n):
            store.note_traffic(BASE, algo="nsga2", pop_size=8)
    pa = str(tmp_path / "a.json")
    a.save(pa)
    b.load(pa)
    (pred,) = b.predicted_keys()
    assert pred.count == 5                    # 3 (loaded) + 2 (local)
    # old snapshots without a traffic key still load fine
    doc = json.loads(open(pa).read())
    del doc["traffic"]
    legacy = str(tmp_path / "legacy.json")
    with open(legacy, "w") as f:
        json.dump(doc, f)
    c = ChampionStore()
    c.load(legacy)
    assert c.predicted_keys() == []


# ------------------------------------------------------ admit resilience

def _patched_sched():
    """A scheduler whose (pre-created) pool we can sabotage before any
    job is submitted (submit() admits eagerly)."""
    sch = PlacementScheduler(n_slots=1, gens_per_step=2)
    key = sch.pool_key("xcvu_test", "nsga2", CFG)
    pool = sch._pool(key, CFG)
    return sch, pool


def test_admit_failure_requeues_with_error_note():
    sch, pool = _patched_sched()
    orig, calls = pool.submit_request, {"n": 0}

    def flaky(req):
        calls["n"] += 1
        if calls["n"] <= 2:                   # fail twice, then recover
            raise RuntimeError("slot allocator hiccup")
        return orig(req)

    pool.submit_request = flaky
    jid = sch.submit("xcvu_test", CFG, seed=0, budget=2)
    job = sch.jobs[jid]
    assert job.attempts == 1                  # first try failed at submit
    assert "slot allocator hiccup" in job.error
    assert not job.failed                     # re-queued, not given up
    done = {j.jid: j for j in sch.run_all()}
    assert done[jid].result is not None and done[jid].done
    assert done[jid].attempts == 2            # recovered on the third try


def test_admit_permanent_failure_surfaces_without_wedging():
    sch, pool = _patched_sched()
    orig = pool.submit_request

    def poison(req):
        if req.seed == 1:
            raise RuntimeError("poisoned job")
        return orig(req)

    pool.submit_request = poison
    bad = sch.submit("xcvu_test", CFG, seed=1, budget=2)
    good = sch.submit("xcvu_test", CFG, seed=2, budget=2)
    done = {j.jid: j for j in sch.run_all()}  # must terminate
    assert done.keys() == {bad, good}
    assert done[good].done and not done[good].failed
    assert done[bad].failed and done[bad].result is None
    assert done[bad].attempts == PlacementScheduler.ADMIT_RETRIES
    assert "poisoned job" in done[bad].error
    assert sch.stats()["jobs_failed"] == 1
    assert not sch.busy


def test_service_stats_report_compile_observability():
    svc = PlacementService(BASE, CFG, n_slots=1, gens_per_step=2)
    svc.submit(seed=0, budget=2)
    _drain(svc)
    s = svc.stats()
    for key in ("blocking_compiles", "blocking_compile_secs",
                "prewarm_compiles", "prewarm_compile_secs",
                "prewarmed_sizes", "time_to_first_gen_ms",
                "compiles_total", "recompiles_total", "compile_secs_total",
                "persistent_cache_dir"):
        assert key in s, key
    assert s["time_to_first_gen_ms"] > 0
    assert s["compiles_total"] >= s["blocking_compiles"]
