"""`hypothesis` if installed, else a tiny deterministic fallback.

The seed image ships without hypothesis, which used to make the whole
suite fail at collection.  Property tests import `given`, `settings`, `st`
from here instead: with hypothesis installed they get the real engine
(shrinking, the full strategy zoo); without it they get a minimal
deterministic sampler covering exactly the strategy subset these tests
use (`st.integers`).  Fallback draws are seeded from a CRC of the test
name, so bare-environment runs are reproducible.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    import zlib

    import numpy as np

    class _IntStrategy:
        def __init__(self, min_value: int, max_value: int):
            self.lo, self.hi = min_value, max_value

        def sample(self, rng: np.random.Generator):
            return int(rng.integers(self.lo, self.hi + 1))

    class _ListStrategy:
        def __init__(self, elements, min_size: int, max_size: int):
            self.elements, self.lo, self.hi = elements, min_size, max_size

        def sample(self, rng: np.random.Generator):
            n = int(rng.integers(self.lo, self.hi + 1))
            return [self.elements.sample(rng) for _ in range(n)]

    class _Strategies:
        @staticmethod
        def integers(min_value: int, max_value: int) -> _IntStrategy:
            return _IntStrategy(min_value, max_value)

        @staticmethod
        def lists(elements, min_size: int = 0,
                  max_size: int = 10) -> _ListStrategy:
            return _ListStrategy(elements, min_size, max_size)

    st = _Strategies()

    _DEFAULT_EXAMPLES = 20

    def given(**strategies):
        def deco(fn):
            # deliberately NOT functools.wraps: pytest must see the
            # zero-argument wrapper signature, not the strategy params
            # (it would otherwise look for fixtures named `seed` etc.)
            def wrapper():
                n = getattr(wrapper, "_max_examples", _DEFAULT_EXAMPLES)
                rng = np.random.default_rng(
                    zlib.crc32(fn.__qualname__.encode()))
                for _ in range(n):
                    fn(**{k: s.sample(rng) for k, s in strategies.items()})
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            # honor @settings applied in either decorator order, like the
            # real hypothesis: below @given it already stamped fn
            wrapper._max_examples = getattr(fn, "_max_examples",
                                            _DEFAULT_EXAMPLES)
            return wrapper
        return deco

    def settings(max_examples: int = _DEFAULT_EXAMPLES, **_ignored):
        def deco(fn):
            fn._max_examples = max_examples
            return fn
        return deco
