import os
import sys

# NOTE: do NOT set XLA_FLAGS / host device count here -- smoke tests and
# benches must see the real single CPU device; only launch/dryrun.py forces
# the 512-device placeholder topology (and only in its own process).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
# make tests/_hypothesis_compat.py importable regardless of invocation dir
sys.path.insert(0, os.path.dirname(__file__))

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)

import pytest  # noqa: E402

from repro.fpga import device, netlist  # noqa: E402


@pytest.fixture(scope="session")
def small_problem():
    return netlist.make_problem(device.get_device("xcvu_test"))


@pytest.fixture(scope="session")
def vu11p_problem():
    return netlist.make_problem(device.get_device("xcvu11p"))
