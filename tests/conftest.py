import os
import sys

# NOTE: do NOT set XLA_FLAGS / host device count here -- smoke tests and
# benches must see whatever topology the environment provides (locally the
# real single CPU device); only launch/dryrun.py forces the 512-device
# placeholder topology (and only in its own process).  Tests that NEED
# multiple devices carry the `multidevice` marker and are skipped below at
# 1 device; the CI quick gate runs the suite under
# XLA_FLAGS=--xla_force_host_platform_device_count=8 so they execute there.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
# make tests/_hypothesis_compat.py importable regardless of invocation dir
sys.path.insert(0, os.path.dirname(__file__))

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)

import pytest  # noqa: E402

from repro.fpga import device, netlist  # noqa: E402


def pytest_collection_modifyitems(config, items):
    """Skip `multidevice` tests cleanly when only one device is visible
    (the default local run); the CI quick gate forces 8 host devices."""
    if jax.device_count() > 1:
        return
    skip = pytest.mark.skip(
        reason="needs >1 JAX device; run with "
               "XLA_FLAGS=--xla_force_host_platform_device_count=8")
    for item in items:
        if "multidevice" in item.keywords:
            item.add_marker(skip)


@pytest.fixture
def island_mesh():
    """A 1-D mesh over every visible device under the islands axis name;
    skips (belt and braces with the marker) at 1 device."""
    if jax.device_count() < 2:
        pytest.skip("needs >1 JAX device for a sharded island mesh")
    from repro.core.islands import AXIS
    from repro.runtime.jaxcompat import make_mesh
    return make_mesh((jax.device_count(),), (AXIS,))


@pytest.fixture(scope="session")
def small_problem():
    return netlist.make_problem(device.get_device("xcvu_test"))


@pytest.fixture(scope="session")
def vu11p_problem():
    return netlist.make_problem(device.get_device("xcvu11p"))
