"""Warm starts + multi-pool scheduler: transfer must pay, routing must not
recompile, and neither may change answers.

Covers the PR's acceptance contracts:
  * `PlacementService.submit(init_state=migrate(base, target, champ))`
    reaches a fixed fitness target in strictly fewer generations than a
    cold start on a sibling device,
  * the scheduler serves a mixed pop_size/algo/device job stream with
    exactly one step compile per distinct pool,
  * per-job results match independent standalone-service runs, and warm
    jobs are reproducible functions of (config, seed, init_state),
  * `core.warmstart` seeds every algorithm family correctly (row-0 seed
    preservation, population padding/truncation, CMA-ES sigma shrink),
  * `transfer.migrate` same-geometry identity + single-column geometries.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import cmaes, evolve, nsga2, transfer, warmstart
from repro.core import genotype as G
from repro.core import objectives as O
from repro.fpga import device, netlist
from repro.serve.placement_service import PlacementService
from repro.serve.scheduler import PlacementScheduler

KEY = jax.random.PRNGKey(0)
BASE = netlist.make_problem(device.get_device("xcvu_test"))
SIB = netlist.make_problem(device.get_device("xcvu_test2"))


@pytest.fixture(scope="module")
def migrated_champion():
    """A converged xcvu_test champion migrated onto the xcvu_test2 sibling
    (shared across tests -- the base run dominates this module's cost)."""
    st, _ = evolve.run(BASE, "nsga2", nsga2.NSGA2Config(pop_size=32),
                       KEY, 100)
    i = int(np.argmin(np.asarray(O.combined_metric(st["objs"]))))
    champ = jax.tree.map(lambda a: a[i], st["pop"])
    g_mig = transfer.migrate(BASE, SIB, champ)
    O.assert_valid(SIB, g_mig)
    return g_mig


# ------------------------------------------------------- transfer.migrate

def test_same_geometry_identity_transfer():
    """migrate(p, p, g) == g on every tier -- including the BRAM parity
    sub-columns whose duplicate x coordinates used to break ties wrong."""
    for prob in (BASE, netlist.make_problem(device.get_device("xcvu3p"))):
        g = G.random_genotype(KEY, prob)
        gm = transfer.migrate(prob, prob, g)
        for tier in ("dist", "loc", "perm"):
            for t in range(3):
                np.testing.assert_array_equal(np.asarray(gm[tier][t]),
                                              np.asarray(g[tier][t]))


def test_single_column_geometry_migrates():
    """n_cols == 1 takes the explicit degenerate path (no epsilon-divide):
    migration to and from a single-URAM-column device stays legal."""
    dev1 = device._make_device("one_col", "T", 1, 1, 6, 1, 4, 2, seed=11)
    p1 = netlist.make_problem(dev1)
    g = G.random_genotype(KEY, BASE)
    gm = transfer.migrate(BASE, p1, g)
    O.assert_valid(p1, gm)
    back = transfer.migrate(p1, BASE, G.random_genotype(KEY, p1))
    O.assert_valid(BASE, back)
    np.testing.assert_array_equal(
        transfer._map_columns(np.array([5.0]), np.array([1.0, 2.0, 3.0])),
        np.zeros(3, np.int64))


# ----------------------------------------------------------- core.warmstart

def test_warm_state_population_row0_is_seed():
    g = G.random_genotype(KEY, SIB)
    pop, fresh = warmstart.canonicalize(SIB, g, 8)
    assert not fresh[0] and fresh[1:].all()
    st = warmstart.warm_state(SIB, "nsga2", nsga2.NSGA2Config(pop_size=8),
                              jax.tree.map(jnp.asarray, pop),
                              jnp.asarray(fresh), KEY,
                              jnp.float32(0.15), jnp.float32(0.25))
    for t in range(3):
        np.testing.assert_array_equal(np.asarray(st["pop"]["perm"][t][0]),
                                      np.asarray(g["perm"][t]))
    assert st["objs"].shape == (8, 2)
    # every jittered member must still decode legally
    for i in range(8):
        O.assert_valid(SIB, jax.tree.map(lambda a: a[i], st["pop"]))


def test_canonicalize_pads_and_truncates_populations():
    pop3 = jax.vmap(lambda k: G.random_genotype(k, SIB))(
        jax.random.split(KEY, 3))
    metric = np.asarray(O.combined_metric(
        O.evaluate_population(SIB, pop3)))
    order = np.argsort(metric, kind="stable")
    padded, fresh = warmstart.canonicalize(SIB, pop3, 8)
    assert fresh.tolist() == [False] * 3 + [True] * 5
    for t in range(3):
        got = np.asarray(padded["perm"][t])
        ref = np.asarray(pop3["perm"][t])[order]          # best-first
        np.testing.assert_array_equal(got[:3], ref)
        np.testing.assert_array_equal(got[3:6], ref)      # cyclic tiling
    # truncation keeps the champions, not the first rows
    trunc, fresh = warmstart.canonicalize(SIB, pop3, 2)
    assert not fresh.any()
    for t in range(3):
        np.testing.assert_array_equal(
            np.asarray(trunc["perm"][t]),
            np.asarray(pop3["perm"][t])[order[:2]])
    row0 = jax.tree.map(lambda a: jnp.asarray(a[0]), trunc)
    np.testing.assert_allclose(
        float(O.combined_metric(O.evaluate(SIB, row0))), metric.min(),
        rtol=1e-6)


def test_warm_state_cmaes_sigma_shrink_and_seed_mean():
    g = G.random_genotype(KEY, SIB)
    cfg = cmaes.CMAESConfig(pop_size=8, sigma0=0.3)
    pop, fresh = warmstart.canonicalize(SIB, g, 1)
    st = warmstart.warm_state(SIB, "cmaes", cfg,
                              jax.tree.map(jnp.asarray, pop),
                              jnp.asarray(fresh), KEY,
                              jnp.float32(0.0), jnp.float32(0.25))
    np.testing.assert_allclose(float(st["sigma"]), 0.3 * 0.25, rtol=1e-6)
    g2 = G.from_flat(SIB, st["mean"])
    for t in range(3):
        np.testing.assert_array_equal(np.asarray(g2["perm"][t]),
                                      np.asarray(g["perm"][t]))
    # warm best is the seed itself, not +inf
    np.testing.assert_allclose(np.asarray(st["best_objs"]),
                               np.asarray(O.evaluate(SIB, g)), rtol=1e-6)


def test_warm_state_zero_jitter_gives_exact_copies():
    g = G.random_genotype(KEY, SIB)
    pop, fresh = warmstart.canonicalize(SIB, g, 4)
    st = warmstart.warm_state(SIB, "nsga2", nsga2.NSGA2Config(pop_size=4),
                              jax.tree.map(jnp.asarray, pop),
                              jnp.asarray(fresh), KEY,
                              jnp.float32(0.0), jnp.float32(1.0))
    for t in range(3):
        ref = np.asarray(g["perm"][t])
        for i in range(4):
            np.testing.assert_array_equal(
                np.asarray(st["pop"]["perm"][t][i]), ref)


# ------------------------------------------------- warm service contracts

def test_warm_start_beats_cold_to_target(migrated_champion):
    """The acceptance criterion: a transfer-seeded job reaches the
    migrated champion's metric in strictly fewer generations than a cold
    start on the sibling device (paper Table II direction)."""
    target = float(O.combined_metric(O.evaluate(SIB, migrated_champion)))
    svc = PlacementService(SIB, nsga2.NSGA2Config(pop_size=16),
                           n_slots=2, gens_per_step=2)
    svc.submit(seed=0, budget=60, target=target)
    svc.submit(seed=0, budget=60, target=target,
               init_state=migrated_champion)
    done = []
    while svc.active.any():
        done.extend(svc.step())
    cold = next(j for j in done if not j.warm)
    warm = next(j for j in done if j.warm)
    assert warm.metric <= target
    assert warm.gens < cold.gens, (
        f"warm {warm.gens} gens !< cold {cold.gens} gens")
    assert svc.step_compiles == 1
    O.assert_valid(SIB, warm.genotype)


def test_warm_jobs_reproducible_and_cotenant_independent(migrated_champion):
    """A warm job's result is a pure function of (cfg, seed, budget,
    init_state): same spec alone or on a loaded pool, same answer."""
    spec = dict(seed=11, budget=6, init_state=migrated_champion,
                cfg=nsga2.NSGA2Config(pop_size=8, real_mut_prob=0.2))
    alone = PlacementService(SIB, nsga2.NSGA2Config(pop_size=8),
                             n_slots=1, gens_per_step=2)
    (job_a,) = alone.run_jobs([spec])
    crowded = PlacementService(SIB, nsga2.NSGA2Config(pop_size=8),
                               n_slots=3, gens_per_step=2)
    others = [dict(seed=7 + i, budget=8) for i in range(3)]
    done = crowded.run_jobs(others[:1] + [spec] + others[1:])
    (job_b,) = [j for j in done if j.seed == 11]
    np.testing.assert_array_equal(job_a.best_objs, job_b.best_objs)
    assert job_a.warm and job_b.warm


def test_warm_start_cmaes_pool(migrated_champion):
    svc = PlacementService(SIB, cmaes.CMAESConfig(pop_size=8),
                           algo="cmaes", n_slots=1, gens_per_step=2)
    seed_metric = float(O.combined_metric(
        O.evaluate(SIB, migrated_champion)))
    svc.submit(seed=0, budget=6, init_state=migrated_champion,
               sigma_shrink=0.25)
    done = []
    while svc.active.any():
        done.extend(svc.step())
    # warm CMA-ES never loses the seed: best-so-far starts there
    assert done[0].metric <= seed_metric * (1 + 1e-6)
    O.assert_valid(SIB, done[0].genotype)


def test_warm_start_reduced_pool_accepts_full_and_reduced_seed():
    g = G.random_genotype(KEY, SIB)
    svc = PlacementService(SIB, nsga2.NSGA2Config(pop_size=8, reduced=True),
                           n_slots=2, gens_per_step=2)
    svc.submit(seed=0, budget=4, init_state=g)              # full genotype
    svc.submit(seed=1, budget=4, init_state=tuple(g["perm"]))  # perm tuple
    done = []
    while svc.active.any():
        done.extend(svc.step())
    assert len(done) == 2
    for j in done:
        O.assert_valid(SIB, j.genotype)
    assert svc.step_compiles == 1


# ------------------------------------------------------------- scheduler

def test_scheduler_routes_mixed_jobs_one_compile_per_pool():
    sch = PlacementScheduler(n_slots=2, gens_per_step=2)
    n = 0
    for dev in ("xcvu_test", "xcvu_test2"):
        for pop in (8, 16):
            for s in range(3):                # 3 jobs > 2 slots: queueing
                sch.submit(dev, nsga2.NSGA2Config(pop_size=pop),
                           seed=s, budget=4)
                n += 1
    sch.submit("xcvu_test2", cmaes.CMAESConfig(pop_size=8), algo="cmaes",
               seed=0, budget=4)
    n += 1
    done = sch.run_all()
    assert len(done) == n and all(j.done for j in done)
    stats = sch.stats()
    # 2 devices x 2 pop sizes + 1 cmaes = 5 distinct static signatures
    assert stats["n_pools"] == 5
    for label, s in stats["pools"].items():
        assert s["step_compiles"] in (1, -1), label
    for job in done:
        O.assert_valid(sch.problem(job.device), job.result.genotype)


def test_scheduler_results_match_standalone_service():
    """Routing through the multi-pool layer must not change any job's
    answer: same (cfg, seed, budget, gens_per_step) -> same objectives."""
    spec = dict(seed=5, budget=6,
                cfg=nsga2.NSGA2Config(pop_size=8, sbx_eta=7.0))
    ref_svc = PlacementService(SIB, spec["cfg"], n_slots=1,
                               gens_per_step=2)
    (ref,) = ref_svc.run_jobs([spec])

    sch = PlacementScheduler(n_slots=2, gens_per_step=2)
    jid = sch.submit("xcvu_test2", spec["cfg"], seed=5, budget=6)
    # co-tenant noise in other pools and the same pool
    sch.submit("xcvu_test2", spec["cfg"], seed=9, budget=4)
    sch.submit("xcvu_test", nsga2.NSGA2Config(pop_size=16), seed=1,
               budget=4)
    done = {j.jid: j for j in sch.run_all()}
    np.testing.assert_array_equal(done[jid].result.best_objs,
                                  ref.best_objs)


def test_scheduler_queues_beyond_slots_and_finishes():
    sch = PlacementScheduler(n_slots=1, gens_per_step=2)
    jids = [sch.submit("xcvu_test", nsga2.NSGA2Config(pop_size=8),
                       seed=i, budget=4) for i in range(4)]
    assert sch.busy
    done = sch.run_all()
    assert sorted(j.jid for j in done) == jids
    assert not sch.busy
    (label,) = sch.stats()["pools"]
    assert sch.stats()["pools"][label]["step_compiles"] in (1, -1)
