"""Per-architecture smoke tests: reduced family-preserving configs run one
forward/train step on CPU, asserting shapes + no NaNs (full configs are only
exercised via the dry-run's ShapeDtypeStructs, never allocated here)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_arch, get_reduced
from repro.models import stubs, transformer as T
from repro.train import optimizer as opt
from repro.train.train_step import make_train_step

KEY = jax.random.PRNGKey(0)


def _batch(red, b=2, s=None):
    s = s or red.period * 8
    toks = jax.random.randint(KEY, (b, s), 0, red.vocab)
    batch = {"tokens": toks, "targets": toks}
    if red.frontend:
        batch["frontend_embeds"] = stubs.synth_frontend(
            KEY, red.frontend, b, red.n_frontend_tokens, red.d_model,
            jnp.float32)
    return batch


@pytest.mark.parametrize("name", ARCHS)
def test_forward_shapes_and_finite(name):
    red = get_reduced(name)
    params = T.init_params(red, KEY, jnp.float32)
    batch = _batch(red)
    logits, aux = T.forward(params, red, batch["tokens"],
                            batch.get("frontend_embeds"), remat=False)
    f = red.n_frontend_tokens if red.frontend else 0
    assert logits.shape == (2, batch["tokens"].shape[1] + f, red.vocab)
    assert np.isfinite(np.asarray(logits)).all()
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("name", ARCHS)
def test_one_train_step_decreases_nothing_nan(name):
    red = get_reduced(name)
    params = T.init_params(red, KEY, jnp.float32)
    ostate = opt.init(params)
    step = jax.jit(make_train_step(red, opt.OptConfig(lr=1e-3,
                                                      warmup_steps=1)))
    batch = _batch(red)
    params2, ostate2, metrics = step(params, ostate, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually moved
    delta = sum(float(jnp.abs(a - b).sum()) for a, b in zip(
        jax.tree.leaves(params), jax.tree.leaves(params2)))
    assert delta > 0


@pytest.mark.parametrize("name", ["yi-6b", "gemma3-12b", "jamba-v0.1-52b",
                                  "rwkv6-1.6b", "deepseek-moe-16b"])
def test_decode_matches_forward(name):
    """Prefill + 1 decode == teacher-forced forward at the last position."""
    red = get_reduced(name)
    params = T.init_params(red, KEY, jnp.float32)
    b, s = 2, 16
    f = red.n_frontend_tokens if red.frontend else 0
    toks = jax.random.randint(KEY, (b, s), 0, red.vocab)
    fe = (stubs.synth_frontend(KEY, red.frontend, b, f, red.d_model,
                               jnp.float32) if red.frontend else None)
    logits, caches, clen = T.prefill(params, red, toks, s + f + 4,
                                     frontend_embeds=fe)
    tok1 = jnp.argmax(logits, -1).astype(jnp.int32)
    logits2, _ = T.decode_step(params, red, tok1, caches, clen)
    full, _ = T.forward(params, red,
                        jnp.concatenate([toks, tok1[:, None]], 1),
                        frontend_embeds=fe, remat=False)
    np.testing.assert_allclose(np.asarray(logits2), np.asarray(full[:, -1]),
                               rtol=1e-3, atol=2e-4)


def test_param_counts_match_assignments():
    expected = {
        "deepseek-moe-16b": (15e9, 18e9),
        "qwen2-moe-a2.7b": (13e9, 17e9),
        "gemma3-12b": (11e9, 14e9),
        "yi-6b": (5.5e9, 6.6e9),
        "mistral-large-123b": (118e9, 127e9),
        "granite-8b": (7.5e9, 9e9),
        "llava-next-34b": (33e9, 36e9),
        "jamba-v0.1-52b": (49e9, 54e9),
        "musicgen-large": (2.5e9, 3.6e9),
        "rwkv6-1.6b": (1.4e9, 1.8e9),
    }
    for name, (lo, hi) in expected.items():
        n = get_arch(name).param_count()
        assert lo <= n <= hi, (name, n)


def test_long_500k_applicability():
    from repro.configs.base import shape_applicable
    runs = {a for a in ARCHS if shape_applicable(get_arch(a), "long_500k")}
    assert runs == {"gemma3-12b", "jamba-v0.1-52b", "rwkv6-1.6b"}
