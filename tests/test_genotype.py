"""Genotype decode legality + encodings, incl. hypothesis property tests."""
import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core import genotype as G
from repro.core import objectives as O
from repro.fpga import device, netlist

PROB = netlist.make_problem(device.get_device("xcvu_test"))


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_random_genotype_always_decodes_legal(seed):
    """Every genotype decodes to a legal placement -- the paper's central
    genotype-design claim (cascade constraints encoded, no legalization)."""
    g = G.random_genotype(jax.random.PRNGKey(seed), PROB)
    O.assert_valid(PROB, g)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_flat_encoding_always_decodes_legal(seed):
    z = jax.random.normal(jax.random.PRNGKey(seed),
                          (PROB.continuous_dim,)) * 2.0
    O.assert_valid(PROB, G.from_flat(PROB, z))


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), total=st.integers(1, 40))
def test_allocation_exact_and_capped(seed, total):
    key = jax.random.PRNGKey(seed)
    caps = jnp.asarray([3, 7, 1, 9, 5, 8, 4, 3], jnp.int32)
    genes = jax.random.normal(key, (8,)) * 3.0
    counts = G.allocate_counts(genes, caps, total)
    assert int(counts.sum()) == total
    assert bool((counts <= caps).all()) and bool((counts >= 0).all())


def test_allocation_follows_genes():
    caps = jnp.full((4,), 100, jnp.int32)
    genes = jnp.asarray([5.0, 0.0, 0.0, 0.0])
    counts = G.allocate_counts(genes, caps, 40)
    assert int(counts[0]) > 30  # dominant gene takes the bulk


def test_flat_roundtrip_perm_exact():
    g = G.random_genotype(jax.random.PRNGKey(3), PROB)
    g2 = G.from_flat(PROB, G.to_flat(PROB, g))
    for t in range(3):
        np.testing.assert_array_equal(np.asarray(g2["perm"][t]),
                                      np.asarray(g["perm"][t]))
        np.testing.assert_allclose(np.asarray(g2["loc"][t]),
                                   np.asarray(g["loc"][t]), atol=1e-5)


def test_reduced_decode_matches_packed_layout():
    g = G.random_genotype(jax.random.PRNGKey(1), PROB)
    bx, by = G.decode_reduced(PROB, g["perm"])
    assert bx.shape == (PROB.n_blocks,)
    assert not bool(jnp.isnan(bx).any() | jnp.isnan(by).any())


def test_mapping_changes_objectives_not_legality():
    """Permuting the mapping must change wirelength (different unit
    groupings) but never legality -- the mapping tier only relabels."""
    key = jax.random.PRNGKey(0)
    g = G.random_genotype(key, PROB)
    o1 = O.evaluate(PROB, g)
    g2 = dict(g)
    g2["perm"] = tuple(jnp.roll(p, 1) for p in g["perm"])
    o2 = O.evaluate(PROB, g2)
    O.assert_valid(PROB, g2)
    assert not np.allclose(np.asarray(o1), np.asarray(o2))


def test_distribution_tier_controls_columns():
    """Cranking one distribution gene concentrates chains in that column."""
    g = G.random_genotype(jax.random.PRNGKey(0), PROB)
    dist = list(g["dist"])
    dist[1] = jnp.zeros_like(dist[1]).at[0].set(10.0)  # DSP column 0
    g2 = {**g, "dist": tuple(dist)}
    bx, _ = G.decode(PROB, g2)
    dsp_x = PROB.geom[1].col_x[0]
    dsp_mask = PROB.blk_type == 1
    frac = np.mean(np.abs(np.asarray(bx)[dsp_mask] - dsp_x) < 1e-4)
    O.assert_valid(PROB, g2)
    assert frac > 0.3  # capacity-capped, but clearly concentrated
