"""Async placement serving: concurrent clients, cancellation, backpressure.

    PYTHONPATH=src python examples/placement_async.py [--clients 12]

The asyncio front-end (`serve.frontend.PlacementFrontend`) owns a
background stepping thread over a `PlacementScheduler`; this example runs
N concurrent client coroutines against it:

  * every client builds a `serve.api.JobRequest` (mixed priorities: every
    third client is "urgent" under the priority stepping policy) and
    `await`s admission -- with `--max-queue` smaller than the client
    count, later clients experience real backpressure (their submit
    suspends until earlier jobs finish),
  * one client streams live progress (`async for u in handle.progress()`:
    generation, best metric, ETA),
  * every `--cancel-every`-th client cancels its job mid-flight and shows
    the slot being reused by the remaining traffic,
  * at the end: per-client submit->result latency percentiles, front-end
    counters, and the fleet's compile discipline (one step compile per
    pool -- concurrency changed latency, never results or compiles).
"""
import argparse
import asyncio
import sys
import time

sys.path.insert(0, "src")

import numpy as np                                           # noqa: E402

from repro.core import nsga2                                 # noqa: E402
from repro.serve.api import JobCancelledError, JobRequest    # noqa: E402
from repro.serve.frontend import PlacementFrontend           # noqa: E402
from repro.serve.scheduler import PlacementScheduler         # noqa: E402


async def client(fe, i, args, latencies):
    rng = np.random.default_rng(1000 + i)
    req = JobRequest(
        device=args.device,
        cfg=nsga2.NSGA2Config(pop_size=args.pop,
                              sbx_eta=float(rng.uniform(5.0, 25.0)),
                              real_mut_prob=float(rng.uniform(0.05, 0.3))),
        seed=1000 + i, budget=args.budget,
        priority=2.0 if i % 3 == 0 else 0.0)
    t0 = time.perf_counter()
    handle = await fe.submit(req)          # may suspend: bounded admission
    t_admit = time.perf_counter() - t0

    if i == 0:                             # one client narrates progress
        async for u in handle.progress():
            eta = f"  eta={u.eta_s:.1f}s" if u.eta_s else ""
            print(f"    job{u.jid}: gen {u.gens}/{u.budget}  "
                  f"metric={u.metric:.3e}{eta}")

    if args.cancel_every and (i + 1) % args.cancel_every == 0:
        # let it run a moment, then cancel mid-flight: the slot frees at
        # the next step boundary and co-tenant jobs are untouched
        await asyncio.sleep(0.05)
        handle.cancel()
        try:
            await handle.wait()
        except JobCancelledError:
            pass
        print(f"  client{i:2d}: [{handle.status.value}]  "
              f"(admitted after {t_admit * 1e3:.0f}ms)")
        return

    result = await handle.wait()
    dt = time.perf_counter() - t0
    latencies.append(dt)
    urgent = " *urgent*" if req.priority > 0 else ""
    print(f"  client{i:2d}: job{handle.jid} {result.gens:3d} gens  "
          f"metric={result.metric:.3e}  {dt * 1e3:.0f}ms"
          f"  (admit {t_admit * 1e3:.0f}ms){urgent}")


async def run(args):
    sched = PlacementScheduler(n_slots=args.slots,
                               gens_per_step=args.gens_per_step,
                               policy="priority")
    latencies = []
    t0 = time.perf_counter()
    async with PlacementFrontend(sched, max_queue=args.max_queue) as fe:
        print(f"{args.clients} clients -> max_queue={args.max_queue}, "
              f"{args.slots} slots (backpressure when the bound is hit)")
        await asyncio.gather(*[client(fe, i, args, latencies)
                               for i in range(args.clients)])
        stats = fe.stats()
    wall = time.perf_counter() - t0        # aclose drained + persisted

    print()
    if latencies:
        p50, p99 = np.percentile(np.array(latencies) * 1e3, [50, 99])
        print(f"submit->result latency: p50={p50:.0f}ms  p99={p99:.0f}ms")
    print(f"{stats['completed']} done / {stats['cancelled']} cancelled in "
          f"{wall:.2f}s ({stats['completed'] / wall:.2f} jobs/s); "
          f"{stats['backpressure_waits']} submits saw backpressure")
    fleet = stats["fleet"]
    compiles = ", ".join(f"{p['sizes']}x{p['step_compiles']}"
                         for p in fleet["pools"].values())
    print(f"fleet: {fleet['n_pools']} pool(s), sizes/step-compiles "
          f"{compiles} -- concurrency changed latency, never compiles")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--device", default="xcvu_test")
    ap.add_argument("--clients", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--pop", type=int, default=16)
    ap.add_argument("--budget", type=int, default=16)
    ap.add_argument("--gens-per-step", type=int, default=4)
    ap.add_argument("--max-queue", type=int, default=8,
                    help="admission bound; < --clients shows backpressure")
    ap.add_argument("--cancel-every", type=int, default=5, metavar="K",
                    help="cancel every K-th client's job mid-flight "
                         "(0 = never)")
    args = ap.parse_args()
    asyncio.run(run(args))


if __name__ == "__main__":
    main()
