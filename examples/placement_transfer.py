"""Transfer learning demo (paper SS IV-D): seed VU3P -> sibling devices.

    PYTHONPATH=src python examples/placement_transfer.py

Optimizes the seed device from scratch, migrates the champion genotype to
each sibling, and compares warm-started vs from-scratch convergence.
"""
import sys
import time

sys.path.insert(0, "src")

import jax                                                   # noqa: E402
import numpy as np                                           # noqa: E402

from repro.core import evolve, nsga2, transfer               # noqa: E402
from repro.core import objectives as O                       # noqa: E402
from repro.fpga import device, netlist                       # noqa: E402

GENS = 40
POP = 24


def best_of(state):
    i = int(np.argmin(np.asarray(O.combined_metric(state["objs"]))))
    return (jax.tree.map(lambda a: a[i], state["pop"]),
            np.asarray(state["objs"][i]))


def main():
    key = jax.random.PRNGKey(0)
    cfg = nsga2.NSGA2Config(pop_size=POP)
    seed_prob = netlist.make_problem(device.get_device("xcvu3p"))
    print(f"optimizing seed xcvu3p ({seed_prob.n_units} units)...")
    st, _ = evolve.run(seed_prob, "nsga2", cfg, key, GENS)
    g_seed, objs = best_of(st)
    print(f"  seed champion: wl2={objs[0]:.3e} bbox={objs[1]:.0f}")

    for dst in ("xcvu5p", "xcvu7p", "xcvu9p"):
        prob = netlist.make_problem(device.get_device(dst))
        gm = transfer.migrate(seed_prob, prob, g_seed)
        O.assert_valid(prob, gm)
        o_mig = np.asarray(O.evaluate(prob, gm))
        o_rand = np.asarray(O.evaluate(
            prob, __import__("repro.core.genotype", fromlist=["g"])
            .random_genotype(key, prob)))
        st0 = transfer.seed_population(prob, gm, key, POP)
        m = evolve.get_algo("nsga2")
        t0 = time.time()
        for i in range(GENS // 4):          # 1/4 the budget suffices
            st0 = m.step(prob, cfg, st0, jax.random.fold_in(key, i))
        _, o_final = best_of(st0)
        print(f"{dst}: migrated seed wl2={o_mig[0]:.3e} "
              f"(random init {o_rand[0]:.3e}); after {GENS//4} warm gens: "
              f"wl2={o_final[0]:.3e} bbox={o_final[1]:.0f} "
              f"[{time.time()-t0:.1f}s]")


if __name__ == "__main__":
    main()
