"""Placement-as-a-service + hyperparameter portfolios, end to end.

    PYTHONPATH=src python examples/placement_service.py [--device xcvu_test]

Part 1 runs the continuous-batching placement service: a pool of job slots
advances many concurrent placement jobs (each with its own seed, budget,
and float hyperparameters) through ONE jitted step program -- requests come
and go with zero recompiles, the serving discipline of `serve/engine.py`
applied to placement traffic.

Part 2 races a hyperparameter portfolio: K NSGA-II configs run as one
vmapped program (`core/portfolio.py`) with early champion selection, and
the champion's placement is validated and summarised.
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import jax                                                   # noqa: E402

from repro.core import nsga2, portfolio, objectives as O     # noqa: E402
from repro.fpga import device, netlist                       # noqa: E402
from repro.serve.placement_service import (                  # noqa: E402
    PlacementService, make_job_specs)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--device", default="xcvu_test",
                    help=f"one of {device.list_devices()}")
    ap.add_argument("--jobs", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--pop", type=int, default=16)
    ap.add_argument("--budget", type=int, default=24)
    args = ap.parse_args()

    prob = netlist.make_problem(device.get_device(args.device))
    print(f"{args.device}: {prob.n_blocks} hard blocks, {prob.n_nets} nets")

    # ---- part 1: continuous-batching service -------------------------
    svc = PlacementService(prob, nsga2.NSGA2Config(pop_size=args.pop),
                           n_slots=args.slots, gens_per_step=4)
    specs = make_job_specs(args.jobs, args.pop, args.budget)
    t0 = time.perf_counter()
    done = svc.run_jobs(specs)
    dt = time.perf_counter() - t0
    print(f"\nservice: {len(done)} jobs over {args.slots} slots "
          f"in {dt:.2f}s -- {len(done)/dt:.2f} jobs/s, "
          f"{svc.stats()['useful_gens']/dt:.1f} gens/s, "
          f"{svc.stats()['step_compiles']} step compile(s)")
    for j in sorted(done, key=lambda j: j.metric)[:4]:
        print(f"  job{j.jid}: metric={j.metric:.3e} "
              f"(wl2={j.best_objs[0]:.3e}, bbox={j.best_objs[1]:.0f})")

    # ---- part 2: portfolio racing ------------------------------------
    cfgs = [nsga2.NSGA2Config(pop_size=args.pop, sbx_eta=eta,
                              real_mut_prob=mp)
            for eta in (5.0, 15.0, 25.0) for mp in (0.1, 0.25)]
    t0 = time.perf_counter()
    res = portfolio.race(prob, "nsga2", cfgs, jax.random.PRNGKey(1),
                         max_gens=args.budget * 2, gens_per_round=6,
                         patience=2)
    dt = time.perf_counter() - t0
    print(f"\nportfolio: {len(cfgs)} configs raced {res.gens} gens "
          f"({res.rounds} rounds) in one vmapped program, {dt:.2f}s")
    print(f"  champion: cfg#{res.champion} "
          f"(sbx_eta={cfgs[res.champion].sbx_eta}, "
          f"mut={cfgs[res.champion].real_mut_prob}) "
          f"metric={res.metric[res.champion]:.3e}")
    g, objs = portfolio.best_genotype(prob, "nsga2",
                                      res.member_state(res.champion),
                                      cfgs[res.champion])
    O.assert_valid(prob, g)
    print("  champion placement validated legal")


if __name__ == "__main__":
    main()
