"""End-to-end driver: train a ~100M-param LM for a few hundred steps.

    PYTHONPATH=src python examples/train_lm.py            # ~100M, 300 steps
    PYTHONPATH=src python examples/train_lm.py --quick    # tiny CI variant

Exercises the full substrate: synthetic pipeline, bf16 params + fp32 AdamW
master, remat'd train step, async atomic checkpoints, failure injection +
recovery (--inject), loss curve printed every 10 steps.
"""
import argparse
import sys

sys.path.insert(0, "src")

import dataclasses                                           # noqa: E402

import jax.numpy as jnp                                      # noqa: E402

from repro.configs import get_arch                           # noqa: E402
from repro.data.pipeline import DataConfig                   # noqa: E402
from repro.train import optimizer as opt                     # noqa: E402
from repro.train.trainer import Trainer, TrainerConfig       # noqa: E402


def model_100m():
    """~100M-param llama-style config (yi family, scaled down)."""
    return dataclasses.replace(
        get_arch("yi-6b"), name="yi-100m",
        n_layers=8, d_model=768, n_heads=12, n_kv_heads=4, d_head=64,
        d_ff=2048, vocab=8192)


def model_tiny():
    return dataclasses.replace(
        model_100m(), name="yi-tiny", n_layers=2, d_model=128, n_heads=4,
        n_kv_heads=2, d_head=32, d_ff=256, vocab=512)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--steps", type=int, default=0)
    ap.add_argument("--batch", type=int, default=0)
    ap.add_argument("--seq", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--inject", type=int, default=None,
                    help="simulate a failure at this step, then recover")
    args = ap.parse_args()

    cfg = model_tiny() if args.quick else model_100m()
    steps = args.steps or (30 if args.quick else 300)
    batch = args.batch or (4 if args.quick else 8)
    seq = args.seq or (64 if args.quick else 256)
    print(f"arch={cfg.name} params={cfg.param_count()/1e6:.1f}M "
          f"steps={steps} batch={batch} seq={seq}")

    tr = Trainer(
        cfg,
        opt.OptConfig(lr=1e-3, warmup_steps=20, total_steps=steps),
        TrainerConfig(steps=steps, ckpt_every=max(steps // 4, 10),
                      ckpt_dir=args.ckpt_dir, log_every=10,
                      inject_failure_at=args.inject,
                      param_dtype=jnp.float32),
        DataConfig(vocab=cfg.vocab, seq_len=seq, global_batch=batch),
    )
    hist = tr.run_with_recovery()
    print("\nstep  loss     lr        grad_norm  s/step")
    for h in hist:
        print(f"{h['step']:5d} {h['loss']:8.4f} {h['lr']:.2e} "
              f"{h['grad_norm']:9.3f} {h['sec_per_step']:.2f}")
    first, last = hist[0]["loss"], hist[-1]["loss"]
    print(f"\nloss {first:.3f} -> {last:.3f} "
          f"({'OK: learning' if last < first else 'WARN: not learning'})")


if __name__ == "__main__":
    main()
