"""Islands-per-job demo: P sub-populations under one service slot.

    PYTHONPATH=src python examples/placement_islands.py [--islands 4]

The control plane (cache, policies, autoscaling) scales placement
*across* jobs; `core.islands` scales quality *within* one.  A slot of an
islands pool holds P independent sub-populations that exchange champions
over a ring every `migrate_every` generations -- one more batch axis in
the same compiled step, so a service step costs the same number of
sequential generations while evaluating P x the candidates.

The demo races the same job spec to the same combined-metric target:

  1. a **single-population** pool (the PR 1 baseline) needs N generations,
  2. an **islands** pool (P sub-populations, ring migration) reaches it
     in measurably fewer -- the bench's `islands` section tracks this
     speedup at equal total evaluations,
  3. `islands=IslandConfig(1, 0)` is the degeneracy check: identical
     results to the single-population pool, bit for bit.
"""
import argparse
import sys

sys.path.insert(0, "src")

import numpy as np                                           # noqa: E402

from repro.core import nsga2                                 # noqa: E402
from repro.core.islands import IslandConfig                  # noqa: E402
from repro.fpga import device, netlist                       # noqa: E402
from repro.serve.placement_service import PlacementService   # noqa: E402


def gens_to_target(prob, cfg, islands, seed, budget, target, gps):
    svc = PlacementService(prob, cfg, n_slots=1, gens_per_step=gps,
                           islands=islands)
    svc.submit(seed=seed, budget=budget, target=target)
    done = []
    while svc.active.any():
        done.extend(svc.step())
    (job,) = done
    return job


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--device", default="xcvu_test")
    ap.add_argument("--pop", type=int, default=16)
    ap.add_argument("--islands", type=int, default=4)
    ap.add_argument("--migrate-every", type=int, default=4)
    ap.add_argument("--budget", type=int, default=48)
    args = ap.parse_args()

    prob = netlist.make_problem(device.get_device(args.device))
    cfg = nsga2.NSGA2Config(pop_size=args.pop)
    gps = 2

    # target: where a single population lands with ~2/3 of the budget --
    # reachable by both contestants, so gens-to-target is well defined
    probe = gens_to_target(prob, cfg, None, seed=123,
                           budget=(2 * args.budget) // 3, target=None,
                           gps=gps)
    target = probe.metric
    print(f"target metric (single-pop, {probe.gens} gens): {target:.3e}\n")

    single = gens_to_target(prob, cfg, None, 0, args.budget, target, gps)
    print(f"single population : {single.gens:3d} gens  "
          f"metric={single.metric:.3e}")

    icfg = IslandConfig(args.islands, args.migrate_every)
    isl = gens_to_target(prob, cfg, icfg, 0, args.budget, target, gps)
    print(f"{args.islands} islands/slot    : {isl.gens:3d} gens  "
          f"metric={isl.metric:.3e}  "
          f"({single.gens / max(isl.gens, 1):.1f}x fewer steps)")

    one = gens_to_target(prob, cfg, IslandConfig(1, 0), 0, args.budget,
                         target, gps)
    same = (one.gens == single.gens
            and np.array_equal(one.best_objs, single.best_objs))
    print(f"islands(P=1)      : {one.gens:3d} gens  "
          f"metric={one.metric:.3e}  "
          f"(identical to single-population: {same})")


if __name__ == "__main__":
    main()
