"""Fleet placement: one design, every UltraScale+ device, one process.

    PYTHONPATH=src python examples/placement_fleet.py [--base xcvu_test]

The paper's transfer result (SS IV-D) turned into a serving pattern: a
single champion is converged once on the base device, then migrated
(`core.transfer`) onto EVERY device in `device.list_devices()` and
submitted warm (`submit(init_state=...)`) through the multi-pool
scheduler (`serve.scheduler.PlacementScheduler`).  Each (device, algo,
static config) signature gets its own lazily created `PlacementService`
pool; pools step round-robin, each compiling its batched step exactly
once.  One process, heterogeneous fleet, warm everywhere.

Default budgets are demo-sized (the big parts get a few generations of
polish, not a converged placement); raise --budget for quality.
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import jax                                                   # noqa: E402

from repro.core import cmaes, nsga2, transfer                # noqa: E402
from repro.core import objectives as O                       # noqa: E402
from repro.fpga import device, netlist                       # noqa: E402
from repro.serve.scheduler import PlacementScheduler         # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--base", default="xcvu_test",
                    help="device to converge the seed champion on")
    ap.add_argument("--base-gens", type=int, default=80)
    ap.add_argument("--pop", type=int, default=8)
    ap.add_argument("--budget", type=int, default=12,
                    help="warm generations per fleet job")
    ap.add_argument("--slots", type=int, default=2)
    args = ap.parse_args()

    base_prob = netlist.make_problem(device.get_device(args.base))
    print(f"converging champion on {args.base} "
          f"({base_prob.n_units} units, {args.base_gens} gens)...")
    champ = transfer.converge_champion(base_prob, jax.random.PRNGKey(0),
                                       4 * args.pop, args.base_gens)
    print(f"  champion metric: "
          f"{float(O.combined_metric(O.evaluate(base_prob, champ))):.3e}")

    sched = PlacementScheduler(n_slots=args.slots, gens_per_step=4)
    jids = {}
    t0 = time.perf_counter()
    for dst in device.list_devices():
        prob = sched.problem(dst)
        g_mig = transfer.migrate(base_prob, prob, champ)
        O.assert_valid(prob, g_mig)
        # every device warm-starts NSGA-II; the base device additionally
        # races CMA-ES from the same seed -- a heterogeneous pool mix
        jids[sched.submit(dst, nsga2.NSGA2Config(pop_size=args.pop),
                          seed=1, budget=args.budget,
                          init_state=g_mig)] = (dst, "nsga2")
        if dst == args.base:
            jids[sched.submit(dst, cmaes.CMAESConfig(pop_size=args.pop),
                              algo="cmaes", seed=1, budget=args.budget,
                              init_state=g_mig)] = (dst, "cmaes")

    done = sched.run_all()
    dt = time.perf_counter() - t0
    print(f"\nfleet: {len(done)} jobs across "
          f"{sched.stats()['n_pools']} pools in {dt:.1f}s")
    for job in sorted(done, key=lambda j: j.jid):
        dst, algo = jids[job.jid]
        r = job.result
        O.assert_valid(sched.problem(dst), r.genotype)
        print(f"  {dst:10s} {algo:6s} {r.gens:3d} warm gens  "
              f"wl2={r.best_objs[0]:.3e}  bbox={r.best_objs[1]:.0f}")
    for label, s in sched.stats()["pools"].items():
        assert s["step_compiles"] in (1, -1), label
    print("every pool compiled its batched step exactly once")


if __name__ == "__main__":
    main()
