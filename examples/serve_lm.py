"""Batched serving demo: slot-based continuous batching with KV caches.

    PYTHONPATH=src python examples/serve_lm.py [--arch yi-6b]

Builds the reduced config of the chosen arch, admits a mixed batch of
prompts through a 4-slot engine, and reports per-request outputs plus
decode throughput.  Greedy engine output is cross-checked against the
offline prefill+decode loop.
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import jax                                                   # noqa: E402
import jax.numpy as jnp                                      # noqa: E402
import numpy as np                                           # noqa: E402

from repro.configs import get_reduced                        # noqa: E402
from repro.models import transformer as T                    # noqa: E402
from repro.serve.engine import Engine                        # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=12)
    args = ap.parse_args()

    cfg = get_reduced(args.arch)
    params = T.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    eng = Engine(cfg, params, n_slots=4, max_len=64, eos_id=-1)

    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, rng.integers(3, 9))
               .astype(np.int32) for _ in range(args.requests)]

    t0 = time.time()
    results = eng.generate(prompts, max_new=args.max_new)
    dt = time.time() - t0
    total_tokens = sum(len(v) for v in results.values())
    print(f"arch={cfg.name} slots=4 requests={len(prompts)}")
    for i in sorted(results):
        print(f"  req{i}: prompt{list(prompts[i])} -> {results[i]}")
    print(f"\n{total_tokens} tokens in {dt:.2f}s "
          f"({total_tokens/dt:.1f} tok/s batched decode on CPU)")


if __name__ == "__main__":
    main()
