"""Quickstart: evolutionary hard-block placement end to end on one device.

    PYTHONPATH=src python examples/quickstart.py [--device xcvu11p]

Runs NSGA-II on the device's repeating rectangle, prints the Pareto front,
the ASCII floorplan of the champion, and its post-placement pipelining
report (the paper's full SS III-B flow minus Vivado).
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import jax                                                   # noqa: E402
import numpy as np                                           # noqa: E402

from repro.core import evolve, nsga2, objectives as O        # noqa: E402
from repro.core import pipelining                            # noqa: E402
from repro.fpga import device, floorplan, netlist            # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--device", default="xcvu_test",
                    help=f"one of {device.list_devices()}")
    ap.add_argument("--generations", type=int, default=60)
    ap.add_argument("--pop", type=int, default=32)
    args = ap.parse_args()

    dev = device.get_device(args.device)
    prob = netlist.make_problem(dev)
    print(f"{dev.name}: {prob.n_units} conv units/rect x {dev.n_rects} "
          f"rects, {prob.n_blocks} hard blocks, {prob.n_nets} nets, "
          f"util={ {k: f'{v:.1%}' for k, v in dev.utilization().items()} }")

    t0 = time.time()
    state, hist = evolve.run(prob, "nsga2",
                             nsga2.NSGA2Config(pop_size=args.pop),
                             jax.random.PRNGKey(0), args.generations)
    objs = np.asarray(state["objs"])
    rank = np.asarray(nsga2.nondominated_rank(state["objs"]))
    print(f"\n{args.generations} generations in {time.time()-t0:.1f}s; "
          f"Pareto front ({int((rank == 0).sum())} candidates):")
    for i in np.where(rank == 0)[0][:8]:
        print(f"  wl2={objs[i,0]:.3e}  max_bbox={objs[i,1]:.0f}")

    best = int(np.argmin(np.asarray(O.combined_metric(state["objs"]))))
    g = jax.tree.map(lambda a: a[best], state["pop"])
    O.assert_valid(prob, g)
    print("\nchampion placement (validated legal):")
    print(floorplan.ascii_floorplan(prob, g, width=100, height=24))

    rep = pipelining.auto_pipeline(prob, g, target_mhz=650.0)
    print(f"\npipelining to 650 MHz: {rep.total_registers} registers, "
          f"achieved {rep.freq_mhz:.0f} MHz "
          f"(unpipelined {pipelining.frequency_at_depth(prob, g, 0):.0f} MHz,"
          f" longest net {rep.max_net_rpm:.0f} RPM)")


if __name__ == "__main__":
    main()
