"""Champion cache demo: cold run -> exact cache hit -> sibling warm hit.

    PYTHONPATH=src python examples/placement_cache.py [--budget 40]

The serving layer's take on the paper's transfer result (SS IV-D,
Table II): a `ChampionStore` attached to the `PlacementScheduler` keys
every harvested champion by the *problem's content signature*
(`fpga.netlist.Problem.signature`), so

  1. a **cold** run on xcvu_test converges normally and writes its
     champion back to the store,
  2. resubmitting the same problem with a reachable `target` is an
     **exact hit**: the store answers in milliseconds with ZERO
     generations and no slot burned,
  3. a job on the sibling device xcvu_test2 (same structural geometry,
     different column layout -- matching `sibling_key`) finds no exact
     entry, so the store auto-migrates the xcvu_test champion
     (`core.transfer.auto_migrate`) into its `init_state`: a **warm hit**
     that reaches the migrated champion's metric in a fraction of the
     cold generations,
  4. the store round-trips through JSON, so a fresh process starts hot.
"""
import argparse
import sys
import tempfile
import time

sys.path.insert(0, "src")

from repro.core import nsga2                                 # noqa: E402
from repro.core import objectives as O                       # noqa: E402
from repro.serve.champion_store import ChampionStore         # noqa: E402
from repro.serve.scheduler import PlacementScheduler         # noqa: E402


def run_one(sch, device, pop, budget, target=None, seed=0):
    t0 = time.perf_counter()
    jid = sch.submit(device, nsga2.NSGA2Config(pop_size=pop), seed=seed,
                     budget=budget, target=target)
    (job,) = (j for j in sch.run_all() if j.jid == jid)
    dt = time.perf_counter() - t0
    return job, dt


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--pop", type=int, default=16)
    ap.add_argument("--budget", type=int, default=40)
    args = ap.parse_args()

    store = ChampionStore()
    sch = PlacementScheduler(n_slots=2, gens_per_step=2, store=store)

    print(f"1) cold run on xcvu_test ({args.budget} gens)...")
    cold, dt = run_one(sch, "xcvu_test", args.pop, args.budget)
    r = cold.result
    print(f"   {r.gens} gens in {dt:.2f}s -> metric {r.metric:.3e} "
          "(champion written back)")

    target = r.metric * 1.001
    print(f"2) same problem again, target {target:.3e} (exact hit)...")
    hit, dt = run_one(sch, "xcvu_test", args.pop, args.budget,
                      target=target, seed=1)
    assert hit.cached and hit.result.gens == 0
    print(f"   served from cache in {dt * 1e3:.1f}ms, "
          f"{hit.result.gens} generations, no slot burned")

    print("3) sibling device xcvu_test2 (warm hit via signature match)...")
    prob_sib = sch.problem("xcvu_test2")
    entry, kind = store.lookup(prob_sib)
    assert kind == "sibling"
    seed_g = store.seed_for(prob_sib, entry)   # what the store will inject
    target = float(O.combined_metric(O.evaluate(prob_sib, seed_g))) * 1.001
    cold_sch = PlacementScheduler(n_slots=2, gens_per_step=2)  # no store
    cold2, _ = run_one(cold_sch, "xcvu_test2", args.pop, args.budget,
                       target=target, seed=2)
    warm, dt = run_one(sch, "xcvu_test2", args.pop, args.budget,
                       target=target, seed=2)
    assert warm.warm_from_cache
    rw = warm.result
    cold_note = ("" if cold2.result.metric <= target
                 else " (budget-capped, never reached it)")
    print(f"   warm-started from the migrated xcvu_test champion: "
          f"{rw.gens} gens to target vs {cold2.result.gens} "
          f"cold{cold_note} ({cold2.result.gens / max(rw.gens, 1):.1f}x "
          "fewer)")

    with tempfile.NamedTemporaryFile(suffix=".json", delete=False) as f:
        path = f.name
    store.save(path)
    hot = ChampionStore(path=path)
    print(f"4) persisted {len(store)} champions -> {path}; a fresh store "
          f"reloads {len(hot)} (fresh processes start hot)")
    print(f"   cache stats: {store.stats()}")


if __name__ == "__main__":
    main()
