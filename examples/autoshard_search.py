"""autoshard: the paper's placement EA applied to TPU sharding layouts.

    PYTHONPATH=src python examples/autoshard_search.py \
        [--arch deepseek-moe-16b] [--shape train_4k] [--verify]

NSGA-II searches the assignment of logical tensor axes to mesh axes against
the analytical roofline cost model (collective-seconds vs bytes/device --
the wirelength^2 / max-bbox analogues), prints the Pareto front and the
champion layout, and with --verify re-lowers the champion through the real
XLA dry-run (the paper's estimate-fast / verify-slow loop; DESIGN.md SS2).
"""
import argparse
import json
import subprocess
import sys
import time

sys.path.insert(0, "src")

from repro.configs import get_arch                           # noqa: E402
from repro.core import autoshard                             # noqa: E402
from repro.sharding import costmodel as cm                   # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-moe-16b")
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--verify", action="store_true",
                    help="compile the champion layout via launch.dryrun")
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    mesh = cm.MeshShape(2 if args.multi_pod else 1, 16, 16)
    t0 = time.time()
    res = autoshard.search(cfg, args.shape, mesh, pop_size=32, n_gens=25)
    dt = time.time() - t0

    print(f"arch={args.arch} shape={args.shape} mesh={mesh} "
          f"({res.evaluations} layout evaluations in {dt:.1f}s -- the "
          f"fast analytical objective; one XLA compile takes ~30-60s)")
    b = res.baseline
    print(f"\nbaseline layout : coll={b.collective_s*1e3:8.2f}ms "
          f"mem={b.memory_s*1e3:8.2f}ms comp={b.compute_s*1e3:8.2f}ms "
          f"resident={b.bytes_per_device/2**30:6.2f}GiB")
    r = res.best_report
    print(f"champion layout : coll={r.collective_s*1e3:8.2f}ms "
          f"mem={r.memory_s*1e3:8.2f}ms comp={r.compute_s*1e3:8.2f}ms "
          f"resident={r.bytes_per_device/2**30:6.2f}GiB")
    print(f"champion rules  : {res.best_rules}")
    print(f"\nPareto front ({len(res.pareto)} layouts):")
    for rules, rep in res.pareto[:8]:
        print(f"  step<={rep.step_s*1e3:7.2f}ms "
              f"res={rep.bytes_per_device/2**30:6.2f}GiB  {rules}")

    if args.verify:
        rules_json = json.dumps({
            k: (list(v) if isinstance(v, tuple) else v)
            for k, v in res.best_rules.items()
            if k in ("batch", "kv_seq")})
        cmd = [sys.executable, "-m", "repro.launch.dryrun",
               "--arch", args.arch, "--shape", args.shape,
               "--rules", rules_json, "--out", "experiments/autoshard"]
        if args.multi_pod:
            cmd.append("--multi-pod")
        print(f"\nverifying champion with a real compile: {' '.join(cmd)}")
        subprocess.run(cmd, check=True, env={"PYTHONPATH": "src",
                                             **__import__("os").environ})


if __name__ == "__main__":
    main()
