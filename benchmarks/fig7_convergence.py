"""Paper Fig. 7b: convergence of wirelength^2/bbox/combined per algorithm.

Emits CSV rows (method, generation, evaluations, wl2, bbox, combined) for
NSGA-II, NSGA-II-reduced, CMA-ES, GA (per-generation) and SA (per-step,
subsampled).  The fidelity target is qualitative: CMA-ES drops bbox within
hundreds of evaluations; NSGA-II reaches the best combined QoR by the end;
reduced-genotype tracks full NSGA-II with a bbox gap (paper SS IV-B2).
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from benchmarks import common
from repro.core import annealing, cmaes, evolve, ga, nsga2


def run(quick: bool = True, seed: int = 0, dev: str = "xcvu11p"):
    prob = common.problem(dev)
    key = jax.random.PRNGKey(seed)
    scale = 0.2 if quick else 1.0
    out = {}
    algos = {
        "nsga2": ("nsga2", nsga2.NSGA2Config(pop_size=32), int(250 * scale)),
        "nsga2_reduced": ("nsga2",
                          nsga2.NSGA2Config(pop_size=32, reduced=True),
                          int(250 * scale)),
        "cmaes": ("cmaes", cmaes.CMAESConfig(pop_size=24), int(500 * scale)),
        "ga": ("ga", ga.GAConfig(pop_size=32), int(250 * scale)),
    }
    for name, (algo, cfg, gens) in algos.items():
        _, hist = evolve.run(prob, algo, cfg, key, gens)
        out[name] = (np.asarray(hist),
                     getattr(cfg, "pop_size", 24))
    sa_cfg = annealing.SAConfig(schedule="hyperbolic", beta=2e-3)
    st0 = annealing.init_state(prob, key, sa_cfg)
    res = annealing.run_chain(prob, sa_cfg, key, int(6000 * scale), st0)
    out["sa"] = (np.asarray(res["history"]), 1)
    return out


def main(quick: bool = True) -> None:
    out = run(quick=quick)
    print("method,generation,evaluations,wl2,bbox,combined")
    for name, (hist, per_gen) in out.items():
        stride = max(1, len(hist) // 60)
        for g in range(0, len(hist), stride):
            wl2, bb = float(hist[g, 0]), float(hist[g, 1])
            print(f"{name},{g},{(g + 1) * per_gen},{wl2:.4g},{bb:.1f},"
                  f"{wl2 * bb:.4g}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    main(quick=not ap.parse_args().full)
