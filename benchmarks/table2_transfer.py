"""Paper Table II / Fig. 10: transfer learning across UltraScale+ devices.

Seed device VU3P is optimized from scratch; siblings VU5P/VU7P/VU9P start
from the migrated genotype.  Metric: evaluations to reach the scratch run's
final QoR (the paper reports 11-14x placement-runtime speedups) plus final
frequency deltas (paper: -2%..+7%).
"""
from __future__ import annotations

import argparse
from typing import Dict

import jax
import numpy as np

from repro.core import evolve, nsga2, pipelining, transfer
from repro.core import objectives as O
from repro.fpga import device, netlist


def _best(state):
    i = int(np.argmin(np.asarray(O.combined_metric(state["objs"]))))
    return jax.tree.map(lambda a: a[i], state["pop"]), state["objs"][i]


def _evals_to_target(hist: np.ndarray, target: float, per_gen: int) -> int:
    comb = hist[:, 0] * hist[:, 1]
    hit = np.where(comb <= target)[0]
    return int((hit[0] + 1) * per_gen) if len(hit) else len(hist) * per_gen


def run(quick: bool = True, seed: int = 0) -> Dict[str, Dict[str, float]]:
    key = jax.random.PRNGKey(seed)
    cfg = nsga2.NSGA2Config(pop_size=32)
    gens = 60 if quick else 300
    seed_dev = "xcvu3p"
    prob_seed = netlist.make_problem(device.get_device(seed_dev))
    st_seed, hist_seed = evolve.run(prob_seed, "nsga2", cfg, key, gens)
    g_seed, _ = _best(st_seed)

    out: Dict[str, Dict[str, float]] = {}
    for dst in ("xcvu5p", "xcvu7p", "xcvu9p"):
        prob = netlist.make_problem(device.get_device(dst))
        # scratch
        st_s, hist_s = evolve.run(prob, "nsga2", cfg,
                                  jax.random.fold_in(key, 1), gens)
        g_s, objs_s = _best(st_s)
        target = float(np.asarray(O.combined_metric(objs_s))) * 1.05
        # transfer: migrate + seeded population, same budget
        g_mig = transfer.migrate(prob_seed, prob, g_seed)
        st0 = transfer.seed_population(prob, g_mig,
                                       jax.random.fold_in(key, 2),
                                       cfg.pop_size)
        m = evolve.get_algo("nsga2")

        def body(st, k):
            st = m.step(prob, cfg, st, k)
            return st, evolve.state_best_objs(st)

        st_t, hist_t = jax.lax.scan(
            body, st0, jax.random.split(jax.random.fold_in(key, 3), gens))
        g_t, objs_t = _best(st_t)

        ev_scratch = _evals_to_target(np.asarray(hist_s), target,
                                      cfg.pop_size)
        ev_transfer = _evals_to_target(np.asarray(hist_t), target,
                                       cfg.pop_size)
        out[dst] = {
            "units": device.get_device(dst).units_total,
            "evals_scratch": ev_scratch,
            "evals_transfer": ev_transfer,
            "speedup": ev_scratch / max(ev_transfer, 1),
            "mhz_scratch": pipelining.frequency_at_depth(prob, g_s, 1),
            "mhz_transfer": pipelining.frequency_at_depth(prob, g_t, 1),
        }
    return out


def main(quick: bool = True) -> None:
    rows = run(quick=quick)
    print("device,units,evals_scratch,evals_transfer,speedup,"
          "mhz_scratch,mhz_transfer,freq_delta_pct")
    for dev_name, r in rows.items():
        dpct = 100 * (r["mhz_transfer"] / r["mhz_scratch"] - 1)
        print(f"{dev_name},{r['units']},{r['evals_scratch']},"
              f"{r['evals_transfer']},{r['speedup']:.1f},"
              f"{r['mhz_scratch']:.0f},{r['mhz_transfer']:.0f},{dpct:+.1f}")
    print("# paper: 11-14x placement speedup, freq delta -2%..+7%")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    main(quick=not ap.parse_args().full)
