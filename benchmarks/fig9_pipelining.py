"""Paper Fig. 9: clock frequency vs pipelining depth per placement method.

Fidelity targets: NSGA-II >= 650 MHz with zero extra stages; others need
>= 1 stage; NSGA-II/CMA-ES reach 750+ MHz by depth 2; everyone saturates
toward the hard-block Fmax with depth.
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from benchmarks import common
from repro.core import annealing, cmaes, evolve, nsga2, pipelining
from repro.core import genotype as G, objectives as O


def best_placements(quick: bool = True, seed: int = 0, dev: str = "xcvu11p"):
    prob = common.problem(dev)
    key = jax.random.PRNGKey(seed)
    scale = 0.25 if quick else 1.0
    out = {}
    st, _ = evolve.run(prob, "nsga2", nsga2.NSGA2Config(pop_size=48),
                       key, int(300 * scale))
    i = int(np.argmin(np.asarray(O.combined_metric(st["objs"]))))
    out["nsga2"] = jax.tree.map(lambda a: a[i], st["pop"])
    cst, _ = evolve.run(prob, "cmaes", cmaes.CMAESConfig(pop_size=24),
                        key, int(600 * scale))
    out["cmaes"] = G.from_flat(prob, cst["best_z"])
    sa_cfg = annealing.SAConfig(schedule="hyperbolic", beta=2e-3)
    st0 = annealing.init_state(prob, key, sa_cfg)
    res = annealing.run_chain(prob, sa_cfg, key, int(8000 * scale), st0)
    out["sa"] = G.from_flat(prob, res["state"]["best_z"])
    out["random(manual-proxy)"] = G.random_genotype(key, prob)
    return prob, out


def main(quick: bool = True) -> None:
    prob, placements = best_placements(quick=quick)
    print("method,depth,freq_mhz,registers")
    for name, g in placements.items():
        sweep = pipelining.depth_sweep(prob, g, 4)
        for d in range(5):
            print(f"{name},{d},{sweep[d]['freq_mhz']:.0f},"
                  f"{sweep[d]['registers']}")
    print("# paper: NSGA-II 650MHz@d0; CMA-ES/SA need >=1 stage; "
          "750+ by d2 for NSGA-II/CMA-ES")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    main(quick=not ap.parse_args().full)
