"""Bench-regression gate for BENCH_placement.json (CI `bench-smoke` job).

    python -m benchmarks.check_bench BENCH_placement.json [--baseline OLD]

Hard failures (exit 1) -- correctness of the serving contracts:
  * a required key is missing from any section (the JSON contract is
    append-only; a vanished key means a silent contract break),
  * `portfolio.champion_matches` / `portfolio.members_match` false
    (batching changed answers),
  * `transfer.warm_beats_cold` false (warm starts stopped helping),
  * `scheduler.all_single_compile` false or a pool reporting more than
    one step compile (continuous batching started recompiling),
  * `service.step_compiles` not 1 (-1 = unknown counter is tolerated),
  * `cache.cache_hit_exact_correct` false (an exact-signature champion
    stopped serving instantly / correctly),
  * `cache.sibling_within_quarter` false (signature-discovered warm
    starts stopped paying the Table II dividend),
  * `policy.policy_deadline_meets_order` false (EDF stopped putting the
    urgent job first, or round-robin started to),
  * `autoscale.compiles_within_ladder` / `autoscale.jobs_match_standalone`
    false (growing a pool recompiled per job or changed answers),
  * `islands.islands_match_single_pop` false (the island model's P=1
    degeneracy to the single-population run broke -- key-stream or
    migration drift) or `islands.islands_single_compile` false (an
    islands pool started recompiling its batched step),
  * `kernels.fused_match_ref` false (the fused Pallas evaluation body
    diverged from the `ref.py` oracles on the real problem extents) or
    `kernels.dom_counts_match_ref` false (the fused domination counts
    diverged from the domination matrix).

Throughput deltas vs `--baseline` are WARN-ONLY: CI machines are noisy,
so jobs/sec regressions are reported for humans, never enforced, and only
compared when the workload shape matches.  A baseline that predates a
newly added throughput key is tolerated with a warning, never a crash --
the contract is append-only, so old baselines are always a key subset.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, List

REQUIRED: Dict[str, List[str]] = {
    "service": ["n_slots", "n_jobs", "pop_size", "budget_gens",
                "gens_per_step", "wall_s", "jobs_per_sec", "gens_per_sec",
                "evals_per_sec", "step_compiles"],
    "portfolio": ["n_configs", "n_gens", "pop_size", "wall_s_batched",
                  "wall_s_independent", "speedup", "champion_matches",
                  "members_match"],
    "transfer": ["base_device", "device", "base_pop", "base_gens",
                 "pop_size", "budget_gens", "gens_per_step",
                 "target_metric", "cold_gens", "warm_gens", "speedup",
                 "warm_beats_cold"],
    "scheduler": ["n_jobs", "n_pools", "budget_gens", "gens_per_step",
                  "n_slots", "wall_s", "jobs_per_sec",
                  "all_single_compile", "pools"],
    "cache": ["base_device", "device", "pop_size", "budget_gens",
              "gens_per_step", "cold_gens", "exact_hit_gens",
              "exact_hit_wall_ms", "sibling_warm_gens", "sibling_speedup",
              "sibling_within_quarter", "cache_hit_exact_correct"],
    "policy": ["device", "budget_gens", "gens_per_step", "n_bulk",
               "rr_urgent_rank", "edf_urgent_rank", "priority_urgent_rank",
               "policy_deadline_meets_order"],
    "autoscale": ["n_jobs", "n_slots_initial", "max_slots", "pop_size",
                  "sizes", "step_compiles", "budget_gens", "gens_per_step",
                  "wall_s", "jobs_per_sec", "compiles_within_ladder",
                  "jobs_match_standalone"],
    "islands": ["n_islands", "migrate_every", "pop_size", "budget_gens",
                "gens_per_step", "target_metric", "single_gens_to_target",
                "islands_gens_to_target", "single_hit_target",
                "islands_hit_target", "wall_s_islands", "speedup_steps",
                "islands_fewer_steps", "islands_single_compile",
                "islands_match_single_pop"],
    "kernels": ["pop_size", "n_nets", "n_units", "n_gids", "reps",
                "evals_per_sec_fused", "evals_per_sec_unfused",
                "fused_speedup", "fused_match_ref",
                "dom_counts_match_ref"],
}
TOP_LEVEL = ["bench", "created_unix", "mode", "device", "jax_version",
             "backend"]

# (section, boolean key, message when false) -- hard correctness gates
BOOLEANS = [
    ("portfolio", "champion_matches",
     "batched results diverged from independent runs"),
    ("portfolio", "members_match",
     "batched results diverged from independent runs"),
    ("transfer", "warm_beats_cold", "warm starts stopped helping"),
    ("cache", "cache_hit_exact_correct",
     "exact-signature cache hit stopped serving instantly/correctly"),
    ("cache", "sibling_within_quarter",
     "sibling warm start no longer reaches target in <= 1/4 cold gens"),
    ("policy", "policy_deadline_meets_order",
     "deadline policy no longer finishes the urgent job first "
     "(or round_robin started to)"),
    ("autoscale", "compiles_within_ladder",
     "autoscaled pool compiled more than once per ladder size"),
    ("autoscale", "jobs_match_standalone",
     "autoscaled pool changed per-job results vs a standalone service"),
    ("islands", "islands_match_single_pop",
     "islands(P=1) diverged from the single-population run"),
    ("islands", "islands_single_compile",
     "islands pool recompiled its batched step (or dropped jobs)"),
    ("kernels", "fused_match_ref",
     "fused Pallas evaluation diverged from the ref oracles"),
    ("kernels", "dom_counts_match_ref",
     "fused domination counts diverged from the domination matrix"),
]

# (section, throughput key, shape keys that must match to compare)
THROUGHPUT = [
    ("service", "jobs_per_sec",
     ["n_slots", "n_jobs", "pop_size", "budget_gens", "gens_per_step"]),
    ("scheduler", "jobs_per_sec",
     ["n_jobs", "n_pools", "budget_gens", "gens_per_step", "n_slots"]),
    ("autoscale", "jobs_per_sec",
     ["n_jobs", "n_slots_initial", "max_slots", "pop_size", "budget_gens",
      "gens_per_step"]),
    ("kernels", "evals_per_sec_fused",
     ["pop_size", "n_nets", "n_units", "n_gids", "reps"]),
    ("kernels", "evals_per_sec_unfused",
     ["pop_size", "n_nets", "n_units", "n_gids", "reps"]),
]
SLOWDOWN_WARN = 0.8        # warn when new < 80% of baseline


def check(report: dict, baseline: dict = None) -> List[str]:
    """Returns the list of hard errors; prints warnings as it goes."""
    errors: List[str] = []
    for key in TOP_LEVEL:
        if key not in report:
            errors.append(f"missing top-level key {key!r}")
    for section, keys in REQUIRED.items():
        sec = report.get(section)
        if not isinstance(sec, dict):
            errors.append(f"missing section {section!r}")
            continue
        for key in keys:
            if key not in sec:
                errors.append(f"missing key {section}.{key}")

    for section, key, why in BOOLEANS:
        if report.get(section, {}).get(key) is False:
            errors.append(f"{section}.{key} is false: {why}")
    sc = report.get("scheduler", {})
    if sc.get("all_single_compile") is False:
        errors.append("scheduler.all_single_compile is false")
    for label, pool in (sc.get("pools") or {}).items():
        if pool.get("step_compiles") not in (1, -1):
            errors.append(f"scheduler pool {label!r} compiled its step "
                          f"{pool.get('step_compiles')} times (want 1)")
    svc = report.get("service", {})
    if svc.get("step_compiles") not in (1, -1, None):
        errors.append(f"service.step_compiles == {svc['step_compiles']} "
                      "(want 1)")

    if baseline:
        for section, key, shape in THROUGHPUT:
            new, old = report.get(section, {}), baseline.get(section, {})
            if key not in new:
                continue
            if not old or key not in old:
                # append-only contract: a baseline captured before this
                # throughput key existed is stale, not broken
                print(f"WARNING: baseline lacks {section}.{key} "
                      "(predates this key?); skipping comparison -- "
                      "regenerate benchmarks/BENCH_smoke_baseline.json")
                continue
            if any(new.get(s) != old.get(s) for s in shape):
                print(f"note: {section} workload shape differs from "
                      "baseline; skipping throughput comparison")
                continue
            if old[key] > 0 and new[key] < old[key] * SLOWDOWN_WARN:
                print(f"WARNING: {section}.{key} regressed "
                      f"{old[key]:.3f} -> {new[key]:.3f} "
                      f"({100 * new[key] / old[key]:.0f}% of baseline; "
                      "warn-only)")
    return errors


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("report", help="fresh BENCH_placement.json to validate")
    ap.add_argument("--baseline", default=None,
                    help="previous BENCH_placement.json for warn-only "
                         "throughput comparison")
    args = ap.parse_args()
    with open(args.report) as f:
        report = json.load(f)
    baseline = None
    if args.baseline:
        try:
            with open(args.baseline) as f:
                baseline = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            print(f"note: baseline unreadable ({e}); skipping comparison")
    errors = check(report, baseline)
    for err in errors:
        print(f"FAIL: {err}")
    if not errors:
        print(f"ok: {args.report} satisfies the bench contract "
              f"({len(REQUIRED)} sections)")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
