"""Bench-regression gate for BENCH_placement.json (CI `bench-smoke` job).

    python -m benchmarks.check_bench BENCH_placement.json [--baseline OLD]

Hard failures (exit 1) -- correctness of the serving contracts:
  * a required key is missing from any section (the JSON contract is
    append-only; a vanished key means a silent contract break),
  * `portfolio.champion_matches` / `portfolio.members_match` false
    (batching changed answers),
  * `transfer.warm_beats_cold` false (warm starts stopped helping),
  * `scheduler.all_single_compile` false or a pool reporting more than
    one step compile (continuous batching started recompiling),
  * `service.step_compiles` not 1 (-1 = unknown counter is tolerated),
  * `cache.cache_hit_exact_correct` false (an exact-signature champion
    stopped serving instantly / correctly),
  * `cache.sibling_within_quarter` false (signature-discovered warm
    starts stopped paying the Table II dividend),
  * `policy.policy_deadline_meets_order` false (EDF stopped putting the
    urgent job first, or round-robin started to),
  * `autoscale.compiles_within_ladder` / `autoscale.jobs_match_standalone`
    false (growing a pool recompiled per job or changed answers),
  * `islands.islands_match_single_pop` false (the island model's P=1
    degeneracy to the single-population run broke -- key-stream or
    migration drift) or `islands.islands_single_compile` false (an
    islands pool started recompiling its batched step),
  * `kernels.fused_match_ref` false (the fused Pallas evaluation body
    diverged from the `ref.py` oracles on the real problem extents) or
    `kernels.dom_counts_match_ref` false (the fused domination counts
    diverged from the domination matrix),
  * `frontend.concurrent_match_sequential` false (32 concurrent clients
    through the async front-end no longer produce bitwise the results of
    a hand-pumped sequential scheduler -- the stepping thread started
    changing answers, not just latency),
  * `compile.recompiles_warm_zero` false (a warm start against a
    populated persistent compilation cache performed a real XLA compile:
    something stopped persisting or the cache key churned) or
    `compile.warm_ttfg_5x` false (the cache-restored time to first
    generation no longer beats a cold start by >= 5x),
  * `telemetry.trace_events_complete` false (a traced run no longer
    reconciles exactly -- a job missed its `job.submit` or its single
    terminal event),
  * `telemetry.jobs_per_sec_off` below 98% of the baseline at an
    identical workload shape -- the ONLY throughput key that hard-fails:
    instrumented-but-disabled serving must stay within 2% of the
    pre-instrumentation build, so any new per-event cost on the disabled
    path is a contract break, not noise.

Compile-budget mode (CI `compile-budget` job):

    python -m benchmarks.check_bench --compile-budget COLD.json WARM.json

validates a cold/warm `benchmarks.compile_probe` pair directly (no full
bench report needed): hard-fails when the warm probe recompiled anything
(`recompiles > 0`) or when its ttfg is not >= 5x better than cold.

Throughput deltas vs `--baseline` are WARN-ONLY: CI machines are noisy,
so jobs/sec regressions are reported for humans, never enforced, and only
compared when the workload shape matches.  A baseline that predates a
newly added throughput key is tolerated with a warning, never a crash --
the contract is append-only, so old baselines are always a key subset.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, List

REQUIRED: Dict[str, List[str]] = {
    "service": ["n_slots", "n_jobs", "pop_size", "budget_gens",
                "gens_per_step", "wall_s", "jobs_per_sec", "gens_per_sec",
                "evals_per_sec", "step_compiles"],
    "portfolio": ["n_configs", "n_gens", "pop_size", "wall_s_batched",
                  "wall_s_independent", "speedup", "champion_matches",
                  "members_match"],
    "transfer": ["base_device", "device", "base_pop", "base_gens",
                 "pop_size", "budget_gens", "gens_per_step",
                 "target_metric", "cold_gens", "warm_gens", "speedup",
                 "warm_beats_cold"],
    "scheduler": ["n_jobs", "n_pools", "budget_gens", "gens_per_step",
                  "n_slots", "wall_s", "jobs_per_sec",
                  "all_single_compile", "pools"],
    "cache": ["base_device", "device", "pop_size", "budget_gens",
              "gens_per_step", "cold_gens", "exact_hit_gens",
              "exact_hit_wall_ms", "sibling_warm_gens", "sibling_speedup",
              "sibling_within_quarter", "cache_hit_exact_correct"],
    "policy": ["device", "budget_gens", "gens_per_step", "n_bulk",
               "rr_urgent_rank", "edf_urgent_rank", "priority_urgent_rank",
               "policy_deadline_meets_order"],
    "autoscale": ["n_jobs", "n_slots_initial", "max_slots", "pop_size",
                  "sizes", "step_compiles", "budget_gens", "gens_per_step",
                  "wall_s", "jobs_per_sec", "compiles_within_ladder",
                  "jobs_match_standalone"],
    "islands": ["n_islands", "migrate_every", "pop_size", "budget_gens",
                "gens_per_step", "target_metric", "single_gens_to_target",
                "islands_gens_to_target", "single_hit_target",
                "islands_hit_target", "wall_s_islands", "speedup_steps",
                "islands_fewer_steps", "islands_single_compile",
                "islands_match_single_pop"],
    "kernels": ["pop_size", "n_nets", "n_units", "n_gids", "reps",
                "evals_per_sec_fused", "evals_per_sec_unfused",
                "fused_speedup", "fused_match_ref",
                "dom_counts_match_ref"],
    "frontend": ["n_clients", "n_slots", "max_queue", "pop_size",
                 "budget_gens", "gens_per_step", "wall_s", "jobs_per_sec",
                 "submit_to_champion_p50_ms", "submit_to_champion_p99_ms",
                 "backpressure_waits", "step_compiles",
                 "concurrent_match_sequential"],
    "telemetry": ["n_clients", "n_slots", "max_queue", "pop_size",
                  "budget_gens", "gens_per_step", "rounds",
                  "jobs_per_sec_off", "jobs_per_sec_on",
                  "enabled_overhead_pct", "trace_events_complete"],
    "compile": ["pop_size", "n_slots", "gens_per_step", "budget_gens",
                "grow_to", "cache_salt", "ttfg_cold_ms", "ttfg_warm_ms",
                "ttfg_speedup", "compiles_cold", "recompiles_cold",
                "compile_secs_cold", "compiles_warm", "recompiles_warm",
                "cache_hits_warm", "compile_secs_warm",
                "recompiles_warm_zero", "warm_ttfg_5x"],
}
TOP_LEVEL = ["bench", "created_unix", "mode", "device", "jax_version",
             "backend"]

# (section, boolean key, message when false) -- hard correctness gates
BOOLEANS = [
    ("portfolio", "champion_matches",
     "batched results diverged from independent runs"),
    ("portfolio", "members_match",
     "batched results diverged from independent runs"),
    ("transfer", "warm_beats_cold", "warm starts stopped helping"),
    ("cache", "cache_hit_exact_correct",
     "exact-signature cache hit stopped serving instantly/correctly"),
    ("cache", "sibling_within_quarter",
     "sibling warm start no longer reaches target in <= 1/4 cold gens"),
    ("policy", "policy_deadline_meets_order",
     "deadline policy no longer finishes the urgent job first "
     "(or round_robin started to)"),
    ("autoscale", "compiles_within_ladder",
     "autoscaled pool compiled more than once per ladder size"),
    ("autoscale", "jobs_match_standalone",
     "autoscaled pool changed per-job results vs a standalone service"),
    ("islands", "islands_match_single_pop",
     "islands(P=1) diverged from the single-population run"),
    ("islands", "islands_single_compile",
     "islands pool recompiled its batched step (or dropped jobs)"),
    ("kernels", "fused_match_ref",
     "fused Pallas evaluation diverged from the ref oracles"),
    ("kernels", "dom_counts_match_ref",
     "fused domination counts diverged from the domination matrix"),
    ("frontend", "concurrent_match_sequential",
     "concurrent submission through the async front-end changed results "
     "vs a hand-pumped sequential scheduler"),
    ("telemetry", "trace_events_complete",
     "a traced front-end run no longer reconciles (missing job.submit or "
     "terminal event for some job)"),
    ("compile", "recompiles_warm_zero",
     "warm start against a populated persistent cache performed a real "
     "XLA compile (persistence or cache keying broke)"),
    ("compile", "warm_ttfg_5x",
     "cache-restored time-to-first-generation no longer >= 5x faster "
     "than cold"),
]

# (section, throughput key, shape keys that must match to compare)
THROUGHPUT = [
    ("service", "jobs_per_sec",
     ["n_slots", "n_jobs", "pop_size", "budget_gens", "gens_per_step"]),
    ("scheduler", "jobs_per_sec",
     ["n_jobs", "n_pools", "budget_gens", "gens_per_step", "n_slots"]),
    ("autoscale", "jobs_per_sec",
     ["n_jobs", "n_slots_initial", "max_slots", "pop_size", "budget_gens",
      "gens_per_step"]),
    ("kernels", "evals_per_sec_fused",
     ["pop_size", "n_nets", "n_units", "n_gids", "reps"]),
    ("kernels", "evals_per_sec_unfused",
     ["pop_size", "n_nets", "n_units", "n_gids", "reps"]),
    ("frontend", "jobs_per_sec",
     ["n_clients", "n_slots", "max_queue", "pop_size", "budget_gens",
      "gens_per_step"]),
    ("telemetry", "jobs_per_sec_on",
     ["n_clients", "n_slots", "max_queue", "pop_size", "budget_gens",
      "gens_per_step", "rounds"]),
]
SLOWDOWN_WARN = 0.8        # warn when new < 80% of baseline

# telemetry's DISABLED path is the one throughput number that hard-fails:
# the observability layer's contract is near-zero cost when off, so a
# >2% regression at an identical shape is a broken contract, not noise
TELEMETRY_OFF_SHAPE = ["n_clients", "n_slots", "max_queue", "pop_size",
                       "budget_gens", "gens_per_step", "rounds"]
TELEMETRY_OFF_FLOOR = 0.98


def check(report: dict, baseline: dict = None) -> List[str]:
    """Returns the list of hard errors; prints warnings as it goes."""
    errors: List[str] = []
    for key in TOP_LEVEL:
        if key not in report:
            errors.append(f"missing top-level key {key!r}")
    for section, keys in REQUIRED.items():
        sec = report.get(section)
        if not isinstance(sec, dict):
            errors.append(f"missing section {section!r}")
            continue
        for key in keys:
            if key not in sec:
                errors.append(f"missing key {section}.{key}")

    for section, key, why in BOOLEANS:
        if report.get(section, {}).get(key) is False:
            errors.append(f"{section}.{key} is false: {why}")
    sc = report.get("scheduler", {})
    if sc.get("all_single_compile") is False:
        errors.append("scheduler.all_single_compile is false")
    for label, pool in (sc.get("pools") or {}).items():
        if pool.get("step_compiles") not in (1, -1):
            errors.append(f"scheduler pool {label!r} compiled its step "
                          f"{pool.get('step_compiles')} times (want 1)")
    svc = report.get("service", {})
    if svc.get("step_compiles") not in (1, -1, None):
        errors.append(f"service.step_compiles == {svc['step_compiles']} "
                      "(want 1)")

    if baseline:
        for section, key, shape in THROUGHPUT:
            new, old = report.get(section, {}), baseline.get(section, {})
            if key not in new:
                continue
            if not old or key not in old:
                # append-only contract: a baseline captured before this
                # throughput key existed is stale, not broken
                print(f"WARNING: baseline lacks {section}.{key} "
                      "(predates this key?); skipping comparison -- "
                      "regenerate benchmarks/BENCH_smoke_baseline.json")
                continue
            if any(new.get(s) != old.get(s) for s in shape):
                print(f"note: {section} workload shape differs from "
                      "baseline; skipping throughput comparison")
                continue
            if old[key] > 0 and new[key] < old[key] * SLOWDOWN_WARN:
                print(f"WARNING: {section}.{key} regressed "
                      f"{old[key]:.3f} -> {new[key]:.3f} "
                      f"({100 * new[key] / old[key]:.0f}% of baseline; "
                      "warn-only)")

        # hard gate: telemetry-off throughput within 2% of baseline
        new, old = (report.get("telemetry") or {}), \
                   (baseline.get("telemetry") or {})
        if "jobs_per_sec_off" in new:
            if "jobs_per_sec_off" not in old:
                print("WARNING: baseline lacks telemetry.jobs_per_sec_off "
                      "(predates the telemetry section?); the disabled-"
                      "overhead gate is unarmed -- regenerate "
                      "benchmarks/BENCH_smoke_baseline.json")
            elif any(new.get(s) != old.get(s)
                     for s in TELEMETRY_OFF_SHAPE):
                print("note: telemetry workload shape differs from "
                      "baseline; disabled-overhead gate skipped")
            elif (old["jobs_per_sec_off"] > 0
                  and new["jobs_per_sec_off"]
                  < old["jobs_per_sec_off"] * TELEMETRY_OFF_FLOOR):
                errors.append(
                    "telemetry.jobs_per_sec_off regressed "
                    f"{old['jobs_per_sec_off']:.3f} -> "
                    f"{new['jobs_per_sec_off']:.3f} (below "
                    f"{100 * TELEMETRY_OFF_FLOOR:.0f}% of baseline): "
                    "telemetry-disabled serving is no longer free")
    overhead = (report.get("telemetry") or {}).get("enabled_overhead_pct")
    if overhead is not None and overhead > 10.0:
        print(f"WARNING: telemetry.enabled_overhead_pct = {overhead}% "
              "(warn-only; tracing-on cost is an exporter concern, not a "
              "serving-contract break)")
    return errors


def check_compile_budget(cold: dict, warm: dict) -> List[str]:
    """Hard gates on a cold/warm `compile_probe` pair (CI compile budget).

    The warm probe ran against the directory the cold probe populated
    (same process shape), so every one of its compile requests must be a
    persistent-cache hit and its time to first generation must be >= 5x
    better than cold.
    """
    errors: List[str] = []
    for name, p in (("cold", cold), ("warm", warm)):
        for key in ("ttfg_ms", "compiles", "recompiles", "cache_hits",
                    "events_seen"):
            if key not in p:
                errors.append(f"{name} probe missing key {key!r}")
    if errors:
        return errors
    if cold["events_seen"] == 0 or warm["events_seen"] == 0:
        errors.append("compile meter saw no events (jax.monitoring keys "
                      "changed?); the budget cannot be verified")
        return errors
    if warm["recompiles"] > 0:
        errors.append(f"recompiles_warm == {warm['recompiles']} (want 0): "
                      f"only {warm['cache_hits']}/{warm['compiles']} "
                      "compile requests were persistent-cache hits")
    speedup = cold["ttfg_ms"] / max(warm["ttfg_ms"], 1e-9)
    if speedup < 5.0:
        errors.append(f"warm ttfg {warm['ttfg_ms']}ms is only {speedup:.2f}x"
                      f" faster than cold {cold['ttfg_ms']}ms (want >= 5x)")
    else:
        print(f"ok: warm ttfg {warm['ttfg_ms']}ms vs cold "
              f"{cold['ttfg_ms']}ms ({speedup:.2f}x), "
              f"recompiles_warm == {warm['recompiles']}")
    return errors


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("report", nargs="?", default=None,
                    help="fresh BENCH_placement.json to validate")
    ap.add_argument("--baseline", default=None,
                    help="previous BENCH_placement.json for warn-only "
                         "throughput comparison")
    ap.add_argument("--compile-budget", nargs=2, default=None,
                    metavar=("COLD", "WARM"),
                    help="validate a cold/warm compile_probe JSON pair "
                         "instead of a bench report")
    args = ap.parse_args()
    if args.compile_budget:
        with open(args.compile_budget[0]) as f:
            cold = json.load(f)
        with open(args.compile_budget[1]) as f:
            warm = json.load(f)
        errors = check_compile_budget(cold, warm)
        for err in errors:
            print(f"FAIL: {err}")
        return 1 if errors else 0
    if args.report is None:
        ap.error("a bench report (or --compile-budget COLD WARM) is "
                 "required")
    with open(args.report) as f:
        report = json.load(f)
    baseline = None
    if args.baseline:
        try:
            with open(args.baseline) as f:
                baseline = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            print(f"note: baseline unreadable ({e}); skipping comparison")
    errors = check(report, baseline)
    for err in errors:
        print(f"FAIL: {err}")
    if not errors:
        print(f"ok: {args.report} satisfies the bench contract "
              f"({len(REQUIRED)} sections)")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
