"""Telemetry-artifact validator (CI `telemetry-smoke` job).

    python -m benchmarks.check_telemetry \
        [--trace T.jsonl] [--metrics M.txt] [--chrome C.json] \
        [--expect-jobs N]

Validates the three exporter artifacts a smoke serving run produces
(`launch/serve.py --trace-file --metrics-dump --chrome-trace`); at least
one artifact must be given.  Hard failures (exit 1):

  * JSONL trace: a line is not a JSON object, or lacks a required field
    (`name`, `kind`, `ts`, `wall`, `attrs`), or carries an unknown
    `kind`, or is a `job.*` event without a `trace` id (pool-lifecycle
    spans are process-scoped and legitimately carry none); a job trace
    with a `job.submit` but no terminal event, with a terminal event but
    no `job.submit`, or with
    MORE than one terminal event (`job.harvested` / `job.cancelled` /
    `job.failed` / `job.cache_hit` are mutually exclusive, exactly-once);
    `--expect-jobs N` additionally pins the number of submitted jobs.
  * Prometheus exposition: a sample line that does not parse as
    `name{labels} value`, a samples block without a preceding
    `# TYPE`/`# HELP` pair, a histogram whose cumulative `_bucket`
    counts decrease with rising `le`, or whose `le="+Inf"` bucket
    disagrees with its `_count`.
  * Chrome trace: not valid JSON, no `traceEvents` list, an event
    missing `name`/`ph`/`ts`/`pid`/`tid`, or unbalanced B/E span pairs.

The checker is deliberately dependency-free (stdlib only) so the CI job
needs nothing beyond the repo itself.
"""
from __future__ import annotations

import argparse
import json
import re
import sys
from collections import defaultdict
from typing import Dict, List

TERMINAL_EVENTS = ("job.harvested", "job.cancelled", "job.failed",
                   "job.cache_hit")
# `trace` is deliberately NOT here: pool-lifecycle spans (pool.build,
# pool.step, ...) are process-scoped and carry no trace id; job.* events
# must carry one, enforced below
EVENT_FIELDS = ("name", "kind", "ts", "wall", "attrs")
KINDS = ("instant", "begin", "end")

# `name{labels} value` / `name value` -- exposition format 0.0.4
_SAMPLE_RE = re.compile(
    r'^([a-zA-Z_:][a-zA-Z0-9_:]*)(\{[^}]*\})?\s+(-?[0-9.eE+-]+|[+-]Inf|NaN)$')
_LE_RE = re.compile(r'le="([^"]+)"')


def check_trace(path: str, expect_jobs: int = None) -> List[str]:
    errors: List[str] = []
    submits: Dict[str, int] = defaultdict(int)
    terminals: Dict[str, List[str]] = defaultdict(list)
    n = 0
    with open(path, encoding="utf-8") as f:
        for i, line in enumerate(f, 1):
            line = line.strip()
            if not line:
                continue
            n += 1
            try:
                ev = json.loads(line)
            except json.JSONDecodeError as e:
                errors.append(f"{path}:{i}: not JSON ({e})")
                continue
            if not isinstance(ev, dict):
                errors.append(f"{path}:{i}: not a JSON object")
                continue
            missing = [k for k in EVENT_FIELDS if k not in ev]
            if missing:
                errors.append(f"{path}:{i}: missing fields {missing}")
                continue
            if ev["kind"] not in KINDS:
                errors.append(f"{path}:{i}: unknown kind {ev['kind']!r}")
            tid = ev.get("trace")
            if ev["name"].startswith("job.") and tid is None:
                errors.append(f"{path}:{i}: {ev['name']} without a "
                              "trace id")
                continue
            if ev["name"] == "job.submit":
                submits[tid] += 1
            elif ev["name"] in TERMINAL_EVENTS:
                terminals[tid].append(ev["name"])
    if n == 0:
        errors.append(f"{path}: empty trace")
    for tid, k in submits.items():
        if k != 1:
            errors.append(f"trace {tid}: {k} job.submit events (want 1)")
        got = terminals.get(tid, [])
        if len(got) != 1:
            errors.append(f"trace {tid}: terminal events {got} "
                          "(want exactly one)")
    for tid, got in terminals.items():
        if tid not in submits:
            errors.append(f"trace {tid}: terminal {got} with no "
                          "job.submit")
    if expect_jobs is not None and len(submits) != expect_jobs:
        errors.append(f"{path}: {len(submits)} submitted jobs "
                      f"(expected {expect_jobs})")
    if not errors:
        print(f"ok: {path}: {n} events, {len(submits)} jobs, every job "
              "reconciles (1 submit + 1 terminal)")
    return errors


def check_metrics(path: str) -> List[str]:
    errors: List[str] = []
    typed: Dict[str, str] = {}           # metric family -> TYPE
    helped = set()
    # histogram family -> label-set-sans-le -> [(le, cum)], _count map
    buckets: Dict[str, Dict[str, list]] = defaultdict(
        lambda: defaultdict(list))
    counts: Dict[str, Dict[str, float]] = defaultdict(dict)
    n_samples = 0
    with open(path, encoding="utf-8") as f:
        for i, line in enumerate(f, 1):
            line = line.rstrip("\n")
            if not line:
                continue
            if line.startswith("# HELP "):
                helped.add(line.split()[2])
                continue
            if line.startswith("# TYPE "):
                parts = line.split()
                typed[parts[2]] = parts[3]
                continue
            if line.startswith("#"):
                continue
            m = _SAMPLE_RE.match(line)
            if not m:
                errors.append(f"{path}:{i}: unparseable sample {line!r}")
                continue
            n_samples += 1
            name, labels, value = m.group(1), m.group(2) or "", m.group(3)
            base = re.sub(r"_(bucket|sum|count)$", "", name)
            if base not in typed:
                errors.append(f"{path}:{i}: sample {name!r} has no "
                              "# TYPE line")
            elif base not in helped:
                errors.append(f"{path}:{i}: sample {name!r} has no "
                              "# HELP line")
            if typed.get(base) == "histogram":
                key = _LE_RE.sub("", labels)
                if name.endswith("_bucket"):
                    le = _LE_RE.search(labels)
                    if le is None:
                        errors.append(f"{path}:{i}: bucket without le=")
                        continue
                    bound = (float("inf") if le.group(1) == "+Inf"
                             else float(le.group(1)))
                    buckets[base][key].append((bound, float(value)))
                elif name.endswith("_count"):
                    counts[base][key] = float(value)
    if n_samples == 0:
        errors.append(f"{path}: no samples")
    for fam, series in buckets.items():
        for key, bs in series.items():
            bs.sort()
            cums = [c for _, c in bs]
            if any(b > a for a, b in zip(cums[1:], cums)):
                errors.append(f"{fam}{key}: cumulative buckets decrease: "
                              f"{cums}")
            if bs and bs[-1][0] != float("inf"):
                errors.append(f"{fam}{key}: no le=+Inf bucket")
            cnt = counts.get(fam, {}).get(key)
            if bs and cnt is not None and bs[-1][1] != cnt:
                errors.append(f"{fam}{key}: +Inf bucket {bs[-1][1]} != "
                              f"_count {cnt}")
    if not errors:
        print(f"ok: {path}: {n_samples} samples, {len(typed)} families, "
              f"{len(buckets)} histogram(s) well-formed")
    return errors


def check_chrome(path: str) -> List[str]:
    errors: List[str] = []
    try:
        with open(path, encoding="utf-8") as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        return [f"{path}: unreadable chrome trace ({e})"]
    evs = doc.get("traceEvents")
    if not isinstance(evs, list) or not evs:
        return [f"{path}: no traceEvents list"]
    depth: Dict[tuple, int] = defaultdict(int)
    for j, ev in enumerate(evs):
        for k in ("name", "ph", "ts", "pid", "tid"):
            if k not in ev:
                errors.append(f"{path}: event {j} missing {k!r}")
        if ev.get("ph") == "B":
            depth[(ev.get("pid"), ev.get("tid"))] += 1
        elif ev.get("ph") == "E":
            key = (ev.get("pid"), ev.get("tid"))
            depth[key] -= 1
            if depth[key] < 0:
                errors.append(f"{path}: event {j}: E with no open B on "
                              f"tid {ev.get('tid')}")
    open_spans = {k: v for k, v in depth.items() if v > 0}
    if open_spans:
        errors.append(f"{path}: unbalanced B/E pairs left open: "
                      f"{open_spans}")
    if not errors:
        print(f"ok: {path}: {len(evs)} events, spans balanced")
    return errors


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--trace", default=None, metavar="JSONL",
                    help="structured-trace JSONL (serve.tracing sink)")
    ap.add_argument("--metrics", default=None, metavar="TXT",
                    help="Prometheus text exposition dump")
    ap.add_argument("--chrome", default=None, metavar="JSON",
                    help="Chrome trace export")
    ap.add_argument("--expect-jobs", type=int, default=None, metavar="N",
                    help="with --trace, require exactly N submitted jobs")
    args = ap.parse_args()
    if not (args.trace or args.metrics or args.chrome):
        ap.error("give at least one of --trace / --metrics / --chrome")
    errors: List[str] = []
    if args.trace:
        errors += check_trace(args.trace, args.expect_jobs)
    if args.metrics:
        errors += check_metrics(args.metrics)
    if args.chrome:
        errors += check_chrome(args.chrome)
    for err in errors:
        print(f"FAIL: {err}")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
