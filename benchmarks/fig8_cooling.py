"""Paper Fig. 8: SA cooling-schedule tuning (4 schedules x parameter sets).

Fidelity target: the hyperbolic schedule yields the best final combined QoR
(the paper selects it for Table I).
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from benchmarks import common
from repro.core import annealing

PARAM_SETS = {
    "exponential": [dict(t0=t0, alpha=a) for t0 in (1.0, 3.0)
                    for a in (0.999, 0.9995)],
    "linear": [dict(t0=t0, n_steps=n) for t0 in (1.0, 3.0)
               for n in (4000, 8000)],
    "hyperbolic": [dict(t0=t0, beta=b) for t0 in (1.0, 3.0)
                   for b in (1e-3, 5e-3)],
    "adaptive": [dict(t0=t0, adapt_target=at) for t0 in (1.0, 3.0)
                 for at in (0.2, 0.4)],
}


def run(quick: bool = True, seed: int = 0, dev: str = "xcvu11p"):
    prob = common.problem(dev)
    key = jax.random.PRNGKey(seed)
    steps = 1500 if quick else 8000
    rows = []
    for sched, psets in PARAM_SETS.items():
        best = np.inf
        for i, ps in enumerate(psets):
            cfg = annealing.SAConfig(schedule=sched, **ps)
            st0 = annealing.init_state(prob, jax.random.fold_in(key, i), cfg)
            res = annealing.run_chain(prob, cfg,
                                      jax.random.fold_in(key, 100 + i),
                                      steps, st0)
            objs = np.asarray(res["state"]["best_objs"])
            comb = float(objs[0] * objs[1])
            rows.append((sched, i, float(objs[0]), float(objs[1]), comb))
            best = min(best, comb)
    return rows


def main(quick: bool = True) -> None:
    rows = run(quick=quick)
    print("schedule,param_set,wl2,bbox,combined")
    for r in rows:
        print(f"{r[0]},{r[1]},{r[2]:.4g},{r[3]:.1f},{r[4]:.4g}")
    bests = {}
    for r in rows:
        bests[r[0]] = min(bests.get(r[0], np.inf), r[4])
    winner = min(bests, key=bests.get)
    print(f"# best schedule: {winner} (paper: hyperbolic)")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    main(quick=not ap.parse_args().full)
