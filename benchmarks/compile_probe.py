"""One cold-start measurement in a fresh process -> one JSON line.

    PYTHONPATH=src python -m benchmarks.compile_probe --cache-dir D [...]

The compile bench (`bench_service.bench_compile`) and the CI
`compile-budget` job both need the SAME measurement twice: "how long does
a fresh process take to serve its first generation, and how many real XLA
compiles did that cost?"  Cold vs warm is decided entirely by what is in
`--cache-dir` when the probe starts -- an empty directory gives the cold
number, a directory populated by a previous probe (or restored by CI's
`actions/cache`) gives the cache-restored number.  Running the probe as a
subprocess is the point: jax's in-memory jit caches die with the process,
so only the persistent compilation cache can make the second run fast.

The probe builds a smoke-shaped `PlacementService`, runs one job through
its first batched step, then exercises one `grow()` ladder rung -- the
full set of programs a restarted serving process replays -- and prints a
single JSON object:

  {"ttfg_ms": ..., "wall_ms": ..., "compiles": ..., "recompiles": ...,
   "cache_hits": ..., "cache_misses": ..., "compile_secs": ...,
   "events_seen": ..., "pop_size": ..., "n_slots": ..., ...}

`recompiles` (real XLA compiles: requests the persistent cache did not
answer) is the number the CI budget pins at 0 for a warm start.
"""
from __future__ import annotations

import argparse
import json
import sys
import time


def probe(cache_dir: str, pop: int, n_slots: int, gens_per_step: int,
          budget: int, device: str, grow_to: int) -> dict:
    from repro.runtime import compile_cache
    compile_cache.enable(cache_dir)
    m = compile_cache.meter().install()

    import jax
    from repro.core import nsga2
    from repro.fpga import device as device_mod
    from repro.fpga import netlist
    from repro.serve.placement_service import PlacementService

    t0 = time.perf_counter()
    prob = netlist.make_problem(device_mod.get_device(device))
    svc = PlacementService(prob, nsga2.NSGA2Config(pop_size=pop),
                           n_slots=n_slots, gens_per_step=gens_per_step)
    svc.submit(seed=0, budget=budget)
    while svc.active.any():
        svc.step()
    ttfg = svc.stats()["time_to_first_gen_ms"]
    if grow_to > n_slots:
        # one ladder rung: a restarted autoscaling process replays these
        # programs too, so the warm budget must cover them
        svc.grow(grow_to)
        svc.submit(seed=1, budget=budget)
        while svc.active.any():
            svc.step()
    wall_ms = (time.perf_counter() - t0) * 1e3
    return {
        "ttfg_ms": round(float(ttfg), 1),
        "wall_ms": round(wall_ms, 1),
        "pop_size": pop, "n_slots": n_slots,
        "gens_per_step": gens_per_step, "budget_gens": budget,
        "device": device, "grow_to": grow_to,
        "jax_version": jax.__version__,
        "backend": jax.default_backend(),
        "cache_salt": compile_cache.cache_salt(),
        "cache_dir": cache_dir,
        **{k: v for k, v in m.stats().items()
           if k != "persistent_cache_dir"},
    }


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--cache-dir", required=True,
                    help="persistent compilation cache directory (empty = "
                         "cold measurement, populated = warm)")
    ap.add_argument("--pop", type=int, default=16)
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--gps", type=int, default=8)
    ap.add_argument("--budget", type=int, default=8)
    ap.add_argument("--device", default="xcvu_test")
    ap.add_argument("--grow-to", type=int, default=16,
                    help="grow the pool to this slot count after the first "
                         "job (0 disables the ladder rung)")
    ap.add_argument("--out", default=None,
                    help="also write the JSON object to this path")
    args = ap.parse_args()
    out = probe(args.cache_dir, args.pop, args.slots, args.gps,
                args.budget, args.device, args.grow_to)
    line = json.dumps(out)
    print(line)
    if args.out:
        with open(args.out, "w") as f:
            f.write(line + "\n")
    return 0


if __name__ == "__main__":
    sys.exit(main())
