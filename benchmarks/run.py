"""Benchmark entry point: one function per paper table/figure + roofline.

`python -m benchmarks.run` executes the quick variants of every benchmark
and finishes with a `name,us_per_call,derived` CSV summary.  Pass --full for
paper-scale budgets.
"""
from __future__ import annotations

import argparse
import io
import time
from contextlib import redirect_stdout


def _run(name, fn, *args, **kw):
    buf = io.StringIO()
    t0 = time.perf_counter()
    with redirect_stdout(buf):
        fn(*args, **kw)
    dt = time.perf_counter() - t0
    print(f"\n===== {name} ({dt:.1f}s) =====")
    print(buf.getvalue().rstrip())
    return dt


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    quick = not args.full

    from benchmarks import (bench_service, fig7_convergence, fig8_cooling,
                            fig9_pipelining, roofline, table1,
                            table2_transfer)

    benches = {
        "placement_service": lambda: bench_service.main(
            mode="quick" if quick else "full"),
        "table1_qor": lambda: table1.main(quick=quick),
        "fig7_convergence": lambda: fig7_convergence.main(quick=quick),
        "fig8_cooling": lambda: fig8_cooling.main(quick=quick),
        "fig9_pipelining": lambda: fig9_pipelining.main(quick=quick),
        "table2_transfer": lambda: table2_transfer.main(quick=quick),
        "roofline": lambda: roofline.main(),
    }
    rows = []
    for name, fn in benches.items():
        if args.only and args.only != name:
            continue
        dt = _run(name, fn)
        rows.append((name, dt * 1e6, "see section above"))

    print("\n===== summary (name,us_per_call,derived) =====")
    for name, us, derived in rows:
        print(f"{name},{us:.0f},{derived}")


if __name__ == "__main__":
    main()
