"""Paper Table I: runtime / wirelength / max-bbox / pipelining registers /
frequency for NSGA-II, NSGA-II (reduced), CMA-ES, SA, GA on the VU11P rect.

Paper reference values are printed alongside for the fidelity check:
CMA-ES fastest (30x vs SA), NSGA-II best bbox + fewest registers, SA best
raw wirelength, GA worst QoR.  Absolute wirelength units differ from the
paper (reconstructed netlist weights); ratios are the reproduction target.
"""
from __future__ import annotations

import argparse
import time
from typing import Dict

import jax
import numpy as np

from benchmarks import common
from repro.core import annealing, cmaes, evolve, ga, nsga2
from repro.core import genotype as G, objectives as O

PAPER = {  # Table I (runtime s, wirelength, bbox, regs, MHz)
    "nsga2": (586, 3.5e3, 1183, 256e3, 733),
    "nsga2_reduced": (323, 3.5e3, 1543, 273e3, 688),
    "cmaes": (51, 4.4e3, 1606, 273e3, 708),
    "sa": (1577, 3.1e3, 1387, 273e3, 711),
    "ga": (850, 9.2e3, 1908, 323e3, 585),
}


def run(quick: bool = True, seed: int = 0, dev: str = "xcvu11p"
        ) -> Dict[str, Dict[str, float]]:
    prob = common.problem(dev)
    key = jax.random.PRNGKey(seed)
    scale = 0.25 if quick else 1.0
    budgets = {
        "nsga2": ("nsga2", nsga2.NSGA2Config(pop_size=48),
                  int(300 * scale)),
        "nsga2_reduced": ("nsga2",
                          nsga2.NSGA2Config(pop_size=48, reduced=True),
                          int(300 * scale)),
        "cmaes": ("cmaes", cmaes.CMAESConfig(pop_size=24),
                  int(600 * scale)),
        "ga": ("ga", ga.GAConfig(pop_size=48), int(300 * scale)),
    }
    rows: Dict[str, Dict[str, float]] = {}
    for name, (algo, cfg, gens) in budgets.items():
        dt, (state, hist) = common.timed(
            evolve.run, prob, algo, cfg, key, gens)
        if algo == "cmaes":
            g, objs = cmaes.best_genotype(prob, state)
        else:
            if getattr(cfg, "reduced", False):
                perms = jax.tree.map(lambda a: a[0], state["pop"])
                g = {"dist": tuple(
                    jax.numpy.log(jax.numpy.asarray(
                        prob.geom[t].col_cap_chains, jax.numpy.float32)
                        + 1e-3) for t in G.TYPES),
                    "loc": tuple(jax.numpy.zeros(prob.geom[t].n_chains)
                                 for t in G.TYPES),
                    "perm": tuple(perms)}
                objs = state["objs"][0]
            else:
                i = int(np.argmin(np.asarray(
                    O.combined_metric(state["objs"]))))
                g = jax.tree.map(lambda a: a[i], state["pop"])
                objs = state["objs"][i]
        row = common.summarize(prob, g, np.asarray(objs))
        row["runtime_s"] = dt
        row["evaluations"] = gens * getattr(cfg, "pop_size", 24)
        rows[name] = row

    # SA: scanned chain
    sa_cfg = annealing.SAConfig(schedule="hyperbolic", t0=2.0, beta=2e-3)
    n_steps = int(8000 * scale)
    st0 = annealing.init_state(prob, key, sa_cfg)
    t0 = time.perf_counter()
    out = annealing.run_chain(prob, sa_cfg, key, n_steps, st0)
    jax.block_until_ready(out["state"]["best_objs"])
    dt = time.perf_counter() - t0
    g = G.from_flat(prob, out["state"]["best_z"])
    row = common.summarize(prob, g, np.asarray(out["state"]["best_objs"]))
    row["runtime_s"] = dt
    row["evaluations"] = n_steps
    rows["sa"] = row
    return rows


def main(quick: bool = True) -> None:
    rows = run(quick=quick)
    hdr = ("method", "runtime_s", "evals", "wirelength", "max_bbox",
           "regs@650", "MHz(d0)", "MHz(piped)")
    print(",".join(hdr))
    for name, r in rows.items():
        print(f"{name},{r['runtime_s']:.1f},{r['evaluations']},"
              f"{r['wirelength']:.0f},{r['max_bbox']:.0f},"
              f"{r['pipeline_regs_650']},{r['freq_mhz_unpipelined']:.0f},"
              f"{r['freq_mhz_pipelined']:.0f}")
    print("\n# paper Table I reference (runtime_s, WL, bbox, regs, MHz):")
    for k, v in PAPER.items():
        print(f"#   {k}: {v}")
    # fidelity ratios mirroring the paper's headline claims
    sa, cm_, ns = rows["sa"], rows["cmaes"], rows["nsga2"]
    red = rows["nsga2_reduced"]
    print("\n# fidelity checks (paper expectation):")
    print(f"# CMA-ES vs SA runtime: {sa['runtime_s']/cm_['runtime_s']:.1f}x "
          f"faster (paper ~30x)")
    print(f"# NSGA-II vs SA bbox: {sa['max_bbox']/ns['max_bbox']:.2f}x "
          f"(paper ~1.2x better)")
    print(f"# NSGA-II regs vs GA: {rows['ga']['pipeline_regs_650']/max(ns['pipeline_regs_650'],1):.2f}x "
          f"(paper ~1.3x)")
    print(f"# reduced-vs-full NSGA-II runtime: "
          f"{ns['runtime_s']/max(red['runtime_s'],1e-9):.2f}x (paper ~1.8x)")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    main(quick=not ap.parse_args().full)
