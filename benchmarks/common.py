"""Shared helpers for the paper-table benchmarks."""
from __future__ import annotations

import time
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core import genotype as G
from repro.fpga import device, netlist


def problem(dev_name: str = "xcvu11p"):
    return netlist.make_problem(device.get_device(dev_name))


def plain_wirelength(prob, g) -> float:
    """Paper Table I 'Wirelength' = sum of weighted Manhattan lengths."""
    bx, by = G.decode(prob, g)
    s, d = jnp.asarray(prob.net_src), jnp.asarray(prob.net_dst)
    w = jnp.asarray(prob.net_w)
    dl = (jnp.abs(bx[s] - bx[d]) + jnp.abs(by[s] - by[d])) * w
    return float(jnp.sum(dl))


def timed(fn, *args, **kw) -> Tuple[float, object]:
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    jax.block_until_ready(jax.tree.leaves(out))
    return time.perf_counter() - t0, out


def summarize(prob, g, objs) -> Dict[str, float]:
    from repro.core import pipelining
    rep = pipelining.auto_pipeline(prob, g, target_mhz=650.0)
    return {
        "wirelength": plain_wirelength(prob, g),
        "wl2": float(objs[0]),
        "max_bbox": float(objs[1]),
        "pipeline_regs_650": rep.total_registers,
        "freq_mhz_unpipelined": pipelining.frequency_at_depth(prob, g, 0),
        "freq_mhz_pipelined": rep.freq_mhz,
    }


def csv_row(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.1f},{derived}"
