"""SSRoofline: aggregate the dry-run artifacts into the three-term table.

Reads experiments/dryrun/*.json (written by launch/dryrun.py), prints the
per-(arch x shape x mesh) roofline terms, flags the dominant bottleneck, and
nominates the three hillclimb cells: worst roofline fraction, most
collective-bound, and most representative of the paper's technique (the
expert-placement MoE cell).

`--kernels` switches to the evaluation-pipeline roofline: an analytic
fused-vs-unfused bytes/flops model of the placement evaluation at the
workload shape recorded in the bench JSON's `kernels` section (achieved
evals/sec vs the memory- and compute-bound peaks).  The unfused path pays
for materialising the gathered endpoint and unit-coordinate tensors in
HBM (written by the gather, read back by the reduction); the fused kernel
keeps those gathers in VMEM, which is the entire bytes-side argument for
fusing the pipeline.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List

PEAK_FLOPS = 197e12
HBM_BW = 819e9


def load(dirname: str = "experiments/dryrun") -> List[Dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(dirname, "*.json"))):
        with open(path) as f:
            rows.append(json.load(f))
    return rows


def table(rows: List[Dict], mesh: str = "pod16x16") -> None:
    print("arch,shape,mesh,status,peak_GiB,compute_s,memory_s,collective_s,"
          "dominant,useful_ratio,roofline_fraction")
    for r in rows:
        if r["mesh"] != mesh:
            continue
        t = r.get("roofline", {})
        if r["status"] != "ok" or "compute_s" not in t:
            # skipped cells and non-LM cells (vu_systolic executes the EA
            # live rather than lowering a step; no roofline terms)
            print(f"{r['arch']},{r['shape']},{r['mesh']},{r['status']},,,,,"
                  f",,")
            continue
        peak = r["memory"]["peak_estimate_bytes"] / 2 ** 30
        # roofline fraction: useful-compute time / achievable step bound
        bound = max(t["compute_s"], t["memory_s"], t["collective_s"])
        chips = 512 if "2x16" in r["mesh"] else 256
        ideal = t["model_flops"] / (chips * PEAK_FLOPS)
        frac = ideal / bound if bound else 0.0
        print(f"{r['arch']},{r['shape']},{r['mesh']},ok,{peak:.2f},"
              f"{t['compute_s']:.4f},{t['memory_s']:.4f},"
              f"{t['collective_s']:.4f},{t['dominant']},"
              f"{t['useful_ratio']:.3f},{frac:.4f}")


def nominate(rows: List[Dict]) -> None:
    ok = [r for r in rows if r["status"] == "ok"
          and r["mesh"] == "pod16x16"
          and "compute_s" in r.get("roofline", {})]

    def frac(r):
        t = r["roofline"]
        bound = max(t["compute_s"], t["memory_s"], t["collective_s"])
        return (t["model_flops"] / (256 * PEAK_FLOPS)) / bound if bound else 0

    def coll_share(r):
        t = r["roofline"]
        tot = t["compute_s"] + t["memory_s"] + t["collective_s"]
        return t["collective_s"] / tot if tot else 0

    worst = min(ok, key=frac)
    collb = max(ok, key=coll_share)
    moe = [r for r in ok
           if r["arch"] == "deepseek-moe-16b" and r["shape"] == "train_4k"]
    print("\n# hillclimb nominations:")
    print(f"#  worst roofline fraction: {worst['arch']} x {worst['shape']} "
          f"({frac(worst):.4f})")
    print(f"#  most collective-bound:   {collb['arch']} x {collb['shape']} "
          f"({100*coll_share(collb):.1f}% of step)")
    if moe:
        print("#  paper-representative:    deepseek-moe-16b x train_4k "
              "(expert placement == hard-block placement)")


def kernel_roofline(bench_path: str = "BENCH_placement.json") -> None:
    """Analytic fused-vs-unfused roofline for the evaluation pipeline.

    Shape comes from the bench JSON's `kernels` section; bytes/flops are
    derived, not measured, so this runs anywhere (no jax import).
    """
    with open(bench_path) as f:
        report = json.load(f)
    k = report.get("kernels")
    if not k:
        print(f"# {bench_path} has no kernels section; re-run "
              "PYTHONPATH=src python -m benchmarks.bench_service first")
        return
    p, n, u, g = (k["pop_size"], k["n_nets"], k["n_units"], k["n_gids"])
    b = g // u                                      # blocks per unit
    f4 = 4                                          # f32/int32 bytes
    # both paths read the same operands once and write two scalars/row
    base = f4 * (2 * p * g + 3 * n + u * b + 2 * p)
    # unfused additionally materialises the gathered endpoint tensors
    # (x1,y1,x2,y2: [P,N] each) and unit tensors (ux,uy: [P,U,B] each),
    # each written by the gather then read back by the reduction
    extra = f4 * 2 * (4 * p * n + 2 * p * u * b)
    flops = p * (9 * n + 6 * u * b)                 # Eq.1 + Eq.2 arithmetic
    print("path,bytes,flops,intensity_f_per_b,mem_bound_s,compute_bound_s,"
          "peak_evals_per_sec,achieved_evals_per_sec,fraction_of_peak")
    for name, nbytes, achieved in (
            ("fused", base, k.get("evals_per_sec_fused")),
            ("unfused", base + extra, k.get("evals_per_sec_unfused"))):
        mem_s = nbytes / HBM_BW
        cmp_s = flops / PEAK_FLOPS
        peak = p / max(mem_s, cmp_s)
        frac = (achieved / peak) if achieved else 0.0
        print(f"{name},{nbytes},{flops},{flops / nbytes:.3f},"
              f"{mem_s:.3e},{cmp_s:.3e},{peak:.3e},"
              f"{achieved or ''},{frac:.2e}")
    print(f"# fused moves {base / (base + extra):.1%} of the unfused HBM "
          f"bytes; intensity gain {(base + extra) / base:.2f}x at equal "
          "flops -- the fused peak is the bound the Pallas kernel chases.")


def main(dirname: str = "experiments/dryrun") -> None:
    rows = load(dirname)
    if not rows:
        print("# no dry-run artifacts found; run "
              "PYTHONPATH=src python -m repro.launch.dryrun --all first")
        return
    for mesh in ("pod16x16", "pod2x16x16"):
        table(rows, mesh)
        print()
    nominate(rows)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--kernels", action="store_true",
                    help="evaluation-pipeline roofline (fused vs unfused)")
    ap.add_argument("--bench", default="BENCH_placement.json",
                    help="bench JSON supplying the kernels workload shape")
    args = ap.parse_args()
    if args.kernels:
        kernel_roofline(args.bench)
    else:
        main(args.dir)
