"""SSRoofline: aggregate the dry-run artifacts into the three-term table.

Reads experiments/dryrun/*.json (written by launch/dryrun.py), prints the
per-(arch x shape x mesh) roofline terms, flags the dominant bottleneck, and
nominates the three hillclimb cells: worst roofline fraction, most
collective-bound, and most representative of the paper's technique (the
expert-placement MoE cell).
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List

PEAK_FLOPS = 197e12
HBM_BW = 819e9


def load(dirname: str = "experiments/dryrun") -> List[Dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(dirname, "*.json"))):
        with open(path) as f:
            rows.append(json.load(f))
    return rows


def table(rows: List[Dict], mesh: str = "pod16x16") -> None:
    print("arch,shape,mesh,status,peak_GiB,compute_s,memory_s,collective_s,"
          "dominant,useful_ratio,roofline_fraction")
    for r in rows:
        if r["mesh"] != mesh:
            continue
        t = r.get("roofline", {})
        if r["status"] != "ok" or "compute_s" not in t:
            # skipped cells and non-LM cells (vu_systolic executes the EA
            # live rather than lowering a step; no roofline terms)
            print(f"{r['arch']},{r['shape']},{r['mesh']},{r['status']},,,,,"
                  f",,")
            continue
        peak = r["memory"]["peak_estimate_bytes"] / 2 ** 30
        # roofline fraction: useful-compute time / achievable step bound
        bound = max(t["compute_s"], t["memory_s"], t["collective_s"])
        chips = 512 if "2x16" in r["mesh"] else 256
        ideal = t["model_flops"] / (chips * PEAK_FLOPS)
        frac = ideal / bound if bound else 0.0
        print(f"{r['arch']},{r['shape']},{r['mesh']},ok,{peak:.2f},"
              f"{t['compute_s']:.4f},{t['memory_s']:.4f},"
              f"{t['collective_s']:.4f},{t['dominant']},"
              f"{t['useful_ratio']:.3f},{frac:.4f}")


def nominate(rows: List[Dict]) -> None:
    ok = [r for r in rows if r["status"] == "ok"
          and r["mesh"] == "pod16x16"
          and "compute_s" in r.get("roofline", {})]

    def frac(r):
        t = r["roofline"]
        bound = max(t["compute_s"], t["memory_s"], t["collective_s"])
        return (t["model_flops"] / (256 * PEAK_FLOPS)) / bound if bound else 0

    def coll_share(r):
        t = r["roofline"]
        tot = t["compute_s"] + t["memory_s"] + t["collective_s"]
        return t["collective_s"] / tot if tot else 0

    worst = min(ok, key=frac)
    collb = max(ok, key=coll_share)
    moe = [r for r in ok
           if r["arch"] == "deepseek-moe-16b" and r["shape"] == "train_4k"]
    print("\n# hillclimb nominations:")
    print(f"#  worst roofline fraction: {worst['arch']} x {worst['shape']} "
          f"({frac(worst):.4f})")
    print(f"#  most collective-bound:   {collb['arch']} x {collb['shape']} "
          f"({100*coll_share(collb):.1f}% of step)")
    if moe:
        print("#  paper-representative:    deepseek-moe-16b x train_4k "
              "(expert placement == hard-block placement)")


def main(dirname: str = "experiments/dryrun") -> None:
    rows = load(dirname)
    if not rows:
        print("# no dry-run artifacts found; run "
              "PYTHONPATH=src python -m repro.launch.dryrun --all first")
        return
    for mesh in ("pod16x16", "pod2x16x16"):
        table(rows, mesh)
        print()
    nominate(rows)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    main(ap.parse_args().dir)
