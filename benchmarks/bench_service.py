"""Placement service + portfolio + transfer + scheduler -> BENCH_placement.json.

    PYTHONPATH=src python -m benchmarks.bench_service [--smoke|--full] [--out P]

The serving-perf trajectory, one JSON per run.  Four measurements:

  * **service**: the continuous-batching placement engine runs >= 8
    concurrent jobs batched into one compiled step; reports jobs/sec,
    generations/sec (active-slot generations actually served) and
    candidate evaluations/sec (gens x pop), all measured after the single
    step compile.
  * **portfolio**: >= 4 hyperparameter configs run as ONE vmapped jitted
    program (`core.portfolio.run_portfolio`); verifies the champion and
    every per-member best match equivalent independent `evolve.run` calls,
    and reports the batched-vs-sequential speedup (both post-compile).
  * **transfer**: warm vs cold gens-to-target on a sibling-device pair
    (paper Table II direction).  A champion converged on the base device
    is migrated (`core.transfer`) and submitted via
    `PlacementService.submit(init_state=...)`; both jobs chase the
    migrated champion's own metric.  `warm_beats_cold` must stay true.
  * **scheduler**: a heterogeneous job stream (mixed pop sizes, algorithms
    and devices) served by `serve.scheduler.PlacementScheduler`; reports
    jobs/sec, the pool count, and compiles-per-pool (each pool's batched
    step must compile exactly once -- `all_single_compile`).
  * **cache**: the champion store (`serve.champion_store`) end to end on
    the sibling pair: a cold run populates the store, an exact-signature
    resubmission is served in O(ms) with ZERO generations and no slot
    (`cache_hit_exact_correct` must stay true), and a sibling-device job
    is warm-started by signature discovery, reaching the migrated
    champion's metric in <= 1/4 the cold generations
    (`sibling_within_quarter`).
  * **policy**: completion-order contract of the stepping policies: an
    urgent (tight-deadline) job submitted after bulk work finishes FIRST
    under `deadline` and does NOT under `round_robin`
    (`policy_deadline_meets_order` must stay true); the `priority` rank
    is reported alongside.
  * **autoscale**: a 1-slot pool absorbing a burst grows along the
    geometric slot ladder; compiles stay bounded by the number of ladder
    sizes (`compiles_within_ladder`) and every job's objectives match a
    standalone never-grown service (`jobs_match_standalone`).
  * **islands**: within-job scaling (`core.islands`): P island
    sub-populations per slot with ring champion migration reach a
    single-population run's combined-metric target in measurably fewer
    wallclock steps at an equal total evaluation budget
    (`speedup_steps`, `islands_fewer_steps`); an islands pool still
    compiles its batched step exactly once (`islands_single_compile`)
    and `islands(P=1)` is bitwise identical to the single-population
    `evolve.run` (`islands_match_single_pop`) -- both hard CI gates.

  * **frontend**: the asyncio job front-end (`serve.frontend`) under 32
    concurrent clients: p50/p99 submit->champion latency and jobs/sec
    (post-compile), with `max_queue < n_clients` so part of the load
    experiences real backpressure.  `concurrent_match_sequential` (every
    client's best objectives bitwise-match the same requests hand-pumped
    through a sequential scheduler) is a hard CI gate: the stepping
    thread changes latency only, never results.

  * **telemetry**: observability overhead contract (`runtime.telemetry` /
    `serve.tracing`).  The same front-end workload runs with tracing OFF
    (the default serving configuration) and ON (in-memory span ring, no
    sink), interleaved best-of-k.  `jobs_per_sec_off` is the number the
    disabled-overhead gate rides on: `check_bench --baseline` HARD-FAILS
    when it regresses more than 2% at an identical workload shape --
    instrumented-but-disabled serving must cost nothing.  The enabled-path
    overhead (`enabled_overhead_pct`) is warn-only trend data.
    `trace_events_complete` (every traced run reconciled exactly: one
    `job.submit` and one terminal event per job) is a hard CI gate.

  * **compile**: cold-start latency vs the persistent compilation cache
    (`runtime.compile_cache`).  Two fresh subprocesses
    (`benchmarks.compile_probe`) share one cache directory: the first
    (cold) populates it, the second (warm) must deserialize instead of
    recompiling.  `recompiles_warm_zero` (the warm probe performed ZERO
    real XLA compiles) and `warm_ttfg_5x` (warm time-to-first-generation
    is >= 5x faster than cold) are hard CI gates; the raw
    cold/warm ttfg and compile counts are trend keys.

  * **kernels**: the fused Pallas evaluation pipeline
    (`kernels.fused_eval`) vs the unfused two-op dispatch at EQUAL
    workload shape: candidate evaluations/sec for both paths (best-of-k
    jitted steady state), the fused/unfused speedup, and two differential
    correctness gates -- the tiled kernel body (interpret mode) matching
    `ref.fused_eval_ref` on the real problem extents (`fused_match_ref`)
    and the fused domination counts matching the domination matrix
    (`dom_counts_match_ref`).  Both booleans are hard CI gates; the
    throughputs are warn-only trend keys.

JSON contract (consumed by `benchmarks.check_bench` and future trend
tooling -- keys are append-only):
  bench, created_unix, mode, device, jax_version, backend,
  service.{n_slots,n_jobs,pop_size,budget_gens,gens_per_step,wall_s,
           jobs_per_sec,gens_per_sec,evals_per_sec,step_compiles},
  portfolio.{n_configs,n_gens,pop_size,wall_s_batched,wall_s_independent,
             speedup,champion_matches,members_match},
  transfer.{base_device,device,base_gens,base_pop,pop_size,budget_gens,
            gens_per_step,target_metric,cold_gens,warm_gens,speedup,
            warm_beats_cold},
  scheduler.{n_jobs,n_pools,budget_gens,gens_per_step,n_slots,wall_s,
             jobs_per_sec,all_single_compile,pools},
  cache.{base_device,device,pop_size,budget_gens,gens_per_step,cold_gens,
         exact_hit_gens,exact_hit_wall_ms,sibling_warm_gens,
         sibling_speedup,sibling_within_quarter,cache_hit_exact_correct},
  policy.{device,budget_gens,gens_per_step,n_bulk,rr_urgent_rank,
          edf_urgent_rank,priority_urgent_rank,policy_deadline_meets_order},
  autoscale.{n_jobs,n_slots_initial,max_slots,pop_size,sizes,
             step_compiles,budget_gens,gens_per_step,wall_s,jobs_per_sec,
             compiles_within_ladder,jobs_match_standalone},
  islands.{n_islands,migrate_every,pop_size,budget_gens,gens_per_step,
           target_metric,single_gens_to_target,islands_gens_to_target,
           single_hit_target,islands_hit_target,wall_s_islands,
           speedup_steps,islands_fewer_steps,islands_single_compile,
           islands_match_single_pop},
  kernels.{pop_size,n_nets,n_units,n_gids,reps,evals_per_sec_fused,
           evals_per_sec_unfused,fused_speedup,fused_match_ref,
           dom_counts_match_ref},
  frontend.{n_clients,n_slots,max_queue,pop_size,budget_gens,
            gens_per_step,wall_s,jobs_per_sec,submit_to_champion_p50_ms,
            submit_to_champion_p99_ms,backpressure_waits,step_compiles,
            concurrent_match_sequential},
  telemetry.{n_clients,n_slots,max_queue,pop_size,budget_gens,
             gens_per_step,rounds,jobs_per_sec_off,jobs_per_sec_on,
             enabled_overhead_pct,trace_events_complete},
  compile.{pop_size,n_slots,gens_per_step,budget_gens,grow_to,cache_salt,
           ttfg_cold_ms,ttfg_warm_ms,ttfg_speedup,compiles_cold,
           recompiles_cold,compile_secs_cold,compiles_warm,
           recompiles_warm,cache_hits_warm,compile_secs_warm,
           recompiles_warm_zero,warm_ttfg_5x}
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from benchmarks import common
from repro.core import evolve, nsga2, cmaes, transfer, portfolio
from repro.core import objectives as O
from repro.core.islands import IslandConfig
from repro.serve.api import JobRequest
from repro.serve.champion_store import ChampionStore
from repro.serve.placement_service import PlacementService, make_job_specs
from repro.serve.scheduler import PlacementScheduler


def bench_service(prob, n_jobs: int, n_slots: int, pop: int, budget: int,
                  gens_per_step: int) -> dict:
    base = nsga2.NSGA2Config(pop_size=pop)
    svc = PlacementService(prob, base, n_slots=n_slots,
                           gens_per_step=gens_per_step)

    # warmup: compiles the init + step programs (one job is enough)
    svc.run_jobs(make_job_specs(1, pop, budget, seed=99))
    svc.useful_gens, svc.total_steps = 0, 0

    t0 = time.perf_counter()
    done = svc.run_jobs(make_job_specs(n_jobs, pop, budget))
    wall = time.perf_counter() - t0
    assert len(done) == n_jobs and all(j.done for j in done)
    s = svc.stats()
    return {
        "n_slots": n_slots, "n_jobs": n_jobs, "pop_size": pop,
        "budget_gens": budget, "gens_per_step": gens_per_step,
        "wall_s": round(wall, 4),
        "jobs_per_sec": round(n_jobs / wall, 3),
        "gens_per_sec": round(s["useful_gens"] / wall, 2),
        "evals_per_sec": round(s["useful_gens"] * pop / wall, 1),
        "step_compiles": s["step_compiles"],
    }


def bench_portfolio(prob, n_cfgs: int, pop: int, n_gens: int) -> dict:
    etas = np.linspace(5.0, 25.0, n_cfgs)
    muts = np.linspace(0.05, 0.3, n_cfgs)
    cfgs = [nsga2.NSGA2Config(pop_size=pop, sbx_eta=float(e),
                              real_mut_prob=float(m))
            for e, m in zip(etas, muts)]
    keys = jax.random.split(jax.random.PRNGKey(7), n_cfgs)

    # batched: warmup compile, then timed steady-state call
    portfolio.run_portfolio(prob, "nsga2", cfgs, keys=keys, n_gens=n_gens)
    t0 = time.perf_counter()
    res = portfolio.run_portfolio(prob, "nsga2", cfgs, keys=keys,
                                  n_gens=n_gens)
    wall_batched = time.perf_counter() - t0

    # independent references (same keys): warmup each, then timed
    ind_best = []
    wall_ind = 0.0
    for cfg, k in zip(cfgs, keys):
        evolve.run(prob, "nsga2", cfg, k, n_gens)          # compile
        dt, (st, _) = common.timed(evolve.run, prob, "nsga2", cfg, k, n_gens)
        wall_ind += dt
        ind_best.append(np.asarray(evolve.state_best_objs(st)))
    ind_best = np.stack(ind_best)
    members_match = bool(np.allclose(res.best_objs, ind_best, rtol=1e-5))
    ind_champ = int(np.argmin(O.combined_metric(ind_best)))
    return {
        "n_configs": n_cfgs, "n_gens": n_gens, "pop_size": pop,
        "wall_s_batched": round(wall_batched, 4),
        "wall_s_independent": round(wall_ind, 4),
        "speedup": round(wall_ind / max(wall_batched, 1e-9), 2),
        "champion_matches": bool(res.champion == ind_champ),
        "members_match": members_match,
    }


def bench_transfer(base_dev: str, dst_dev: str, base_pop: int,
                   base_gens: int, pop: int, budget: int,
                   gens_per_step: int) -> dict:
    """Warm vs cold gens-to-target on a sibling pair (paper Table II).

    Target = the migrated champion's own combined metric: the warm job
    carries it from generation 0 (elitist seeding), the cold job has to
    re-discover it from random init.
    """
    base_prob = common.problem(base_dev)
    dst_prob = common.problem(dst_dev)
    champ = transfer.converge_champion(base_prob, jax.random.PRNGKey(0),
                                       base_pop, base_gens)
    g_mig = transfer.migrate(base_prob, dst_prob, champ)
    target = float(O.combined_metric(O.evaluate(dst_prob, g_mig)))

    svc = PlacementService(dst_prob, nsga2.NSGA2Config(pop_size=pop),
                           n_slots=2, gens_per_step=gens_per_step)
    svc.submit_request(JobRequest(seed=0, budget=budget, target=target))
    svc.submit_request(JobRequest(seed=0, budget=budget, target=target,
                                  init_state=g_mig))
    done = []
    while svc.active.any():
        done.extend(svc.step())
    cold = next(j for j in done if not j.warm)
    warm = next(j for j in done if j.warm)
    return {
        "base_device": base_dev, "device": dst_dev,
        "base_pop": base_pop, "base_gens": base_gens, "pop_size": pop,
        "budget_gens": budget, "gens_per_step": gens_per_step,
        "target_metric": target,
        "cold_gens": cold.gens, "warm_gens": warm.gens,
        "speedup": round(cold.gens / max(warm.gens, 1), 2),
        "warm_beats_cold": bool(warm.gens < cold.gens),
    }


def bench_scheduler(devices, pops, jobs_per_pool: int, budget: int,
                    n_slots: int, gens_per_step: int) -> dict:
    """Heterogeneous stream: mixed pop sizes x algos x devices, one
    process.  Pools compile lazily in a warmup wave; the timed wave then
    measures steady-state fleet throughput."""
    sch = PlacementScheduler(n_slots=n_slots, gens_per_step=gens_per_step)

    def combos():
        for dev in devices:
            for p in pops:
                yield dev, "nsga2", nsga2.NSGA2Config(pop_size=p)
            yield dev, "cmaes", cmaes.CMAESConfig(pop_size=pops[0])

    # warmup wave: every pool compiles its init + step once
    for dev, algo, cfg in combos():
        sch.submit_request(JobRequest(device=dev, cfg=cfg, algo=algo,
                                      seed=999, budget=gens_per_step))
    sch.run_all()

    n_jobs = 0
    t0 = time.perf_counter()
    for dev, algo, cfg in combos():
        for s in range(jobs_per_pool):
            sch.submit_request(JobRequest(device=dev, cfg=cfg,
                                          algo=algo, seed=s, budget=budget))
            n_jobs += 1
    done = sch.run_all()
    wall = time.perf_counter() - t0
    assert len(done) == n_jobs and all(j.done for j in done)
    stats = sch.stats()
    pools = {label: {"step_compiles": ps["step_compiles"],
                     "useful_gens": ps["useful_gens"]}
             for label, ps in stats["pools"].items()}
    return {
        "n_jobs": n_jobs, "n_pools": stats["n_pools"],
        "budget_gens": budget, "gens_per_step": gens_per_step,
        "n_slots": n_slots,
        "wall_s": round(wall, 4),
        "jobs_per_sec": round(n_jobs / wall, 3),
        "all_single_compile": all(
            p["step_compiles"] in (1, -1) for p in pools.values()),
        "pools": pools,
    }


def bench_cache(base_dev: str, sib_dev: str, pop: int, budget: int,
                gens_per_step: int) -> dict:
    """Champion store end to end: cold run -> exact hit -> sibling warm.

    The exact hit must serve with zero generations and no pool (O(ms));
    the sibling warm start must reach the migrated champion's metric in
    <= 1/4 of the cold gens-to-target (paper Table II direction, now
    decided inside the serving layer by content signatures).
    """
    store = ChampionStore()
    sch = PlacementScheduler(n_slots=2, gens_per_step=gens_per_step,
                             store=store)
    cfg = nsga2.NSGA2Config(pop_size=pop)
    jid_cold = sch.submit_request(JobRequest(device=base_dev, cfg=cfg,
                                             seed=0, budget=budget))
    done = {j.jid: j for j in sch.run_all()}
    champion_metric = done[jid_cold].result.metric

    # exact hit: same signature, reachable target -> instant finished job
    pools_before = sch.stats()["n_pools"]
    target = champion_metric * 1.001
    t0 = time.perf_counter()
    jid_hit = sch.submit_request(JobRequest(device=base_dev, cfg=cfg,
                                            seed=1, budget=budget,
                                            target=target))
    done_hit = {j.jid: j for j in sch.run_all()}
    wall_hit = time.perf_counter() - t0
    hit = done_hit[jid_hit]
    cache_hit_exact_correct = bool(
        hit.cached and hit.result.gens == 0
        and hit.result.metric <= target
        and sch.stats()["n_pools"] == pools_before)

    # sibling warm hit vs a cold control, both chasing the migrated
    # champion's own metric (the store discovers the donor by sibling_key)
    sib_prob = sch.problem(sib_dev)
    entry, kind = store.lookup(sib_prob)
    assert kind == "sibling", kind
    g_mig = store.seed_for(sib_prob, entry)
    sib_target = float(O.combined_metric(O.evaluate(sib_prob, g_mig)))
    cold_sch = PlacementScheduler(n_slots=2, gens_per_step=gens_per_step)
    jid = cold_sch.submit_request(JobRequest(device=sib_dev, cfg=cfg,
                                             seed=0, budget=budget,
                                             target=sib_target))
    cold_gens = {j.jid: j for j in cold_sch.run_all()}[jid].result.gens
    jid = sch.submit_request(JobRequest(device=sib_dev, cfg=cfg,
                                        seed=0, budget=budget,
                                        target=sib_target))
    warm_job = {j.jid: j for j in sch.run_all()}[jid]
    assert warm_job.warm_from_cache
    warm_gens = warm_job.result.gens
    return {
        "base_device": base_dev, "device": sib_dev, "pop_size": pop,
        "budget_gens": budget, "gens_per_step": gens_per_step,
        "cold_gens": cold_gens,
        "exact_hit_gens": hit.result.gens,
        "exact_hit_wall_ms": round(wall_hit * 1e3, 3),
        "sibling_warm_gens": warm_gens,
        "sibling_speedup": round(cold_gens / max(warm_gens, 1), 2),
        "sibling_within_quarter": bool(4 * warm_gens <= cold_gens),
        "cache_hit_exact_correct": cache_hit_exact_correct,
    }


def bench_policy(dev: str, budget: int, gens_per_step: int,
                 n_bulk: int = 2) -> dict:
    """Completion-order contract: an urgent job submitted AFTER bulk work
    finishes first under `deadline` (EDF) and not under `round_robin`."""
    bulk_cfg = nsga2.NSGA2Config(pop_size=16)
    urgent_cfg = nsga2.NSGA2Config(pop_size=8)

    def urgent_rank(policy) -> int:
        sch = PlacementScheduler(n_slots=1, gens_per_step=gens_per_step,
                                 policy=policy)
        for s in range(n_bulk):
            sch.submit_request(JobRequest(device=dev, cfg=bulk_cfg, seed=s,
                                          budget=budget, deadline=1e9,
                                          priority=0.0))
        urgent = sch.submit_request(JobRequest(device=dev, cfg=urgent_cfg,
                                               seed=0, budget=budget,
                                               deadline=1.0, priority=10.0))
        order = [j.jid for j in sch.run_all()]
        return order.index(urgent)

    rr = urgent_rank("round_robin")
    edf = urgent_rank("deadline")
    prio = urgent_rank("priority")
    return {
        "device": dev, "budget_gens": budget,
        "gens_per_step": gens_per_step, "n_bulk": n_bulk,
        "rr_urgent_rank": rr, "edf_urgent_rank": edf,
        "priority_urgent_rank": prio,
        "policy_deadline_meets_order": bool(edf == 0 and rr > 0),
    }


def bench_autoscale(dev: str, n_jobs: int, pop: int, budget: int,
                    gens_per_step: int, max_slots: int = 4) -> dict:
    """Queue-depth autoscaling: a 1-slot pool absorbs a burst by growing
    along the geometric slot ladder.  Compiles stay O(#sizes) and every
    job's result must match a standalone never-grown service."""
    prob = common.problem(dev)
    cfg = nsga2.NSGA2Config(pop_size=pop)
    sch = PlacementScheduler(n_slots=1, gens_per_step=gens_per_step,
                             autoscale=True, autoscale_threshold=2,
                             max_slots=max_slots)
    t0 = time.perf_counter()
    jids = [sch.submit_request(JobRequest(device=dev, cfg=cfg, seed=i,
                                          budget=budget))
            for i in range(n_jobs)]
    done = {j.jid: j for j in sch.run_all()}
    wall = time.perf_counter() - t0
    assert sorted(done) == jids
    (pool_stats,) = sch.stats()["pools"].values()
    sizes = pool_stats["sizes"]
    compiles = pool_stats["step_compiles"]

    ref = PlacementService(prob, cfg, n_slots=1,
                           gens_per_step=gens_per_step)
    ref_objs = {j.seed: j.best_objs for j in ref.run_jobs(
        [dict(seed=i, budget=budget) for i in range(n_jobs)])}
    jobs_match = all(
        np.allclose(done[j].result.best_objs,
                    ref_objs[done[j].result.seed], rtol=1e-5)
        for j in jids)
    return {
        "n_jobs": n_jobs, "n_slots_initial": 1, "max_slots": max_slots,
        "pop_size": pop, "sizes": sizes, "step_compiles": compiles,
        "budget_gens": budget, "gens_per_step": gens_per_step,
        "wall_s": round(wall, 4),
        "jobs_per_sec": round(n_jobs / wall, 3),
        "compiles_within_ladder": bool(compiles == -1
                                       or compiles <= len(sizes)),
        "jobs_match_standalone": bool(jobs_match),
    }


def _islands_match_single_pop(prob, pop: int, n_gens: int = 6) -> bool:
    """Degeneracy gate: `islands(P=1)` is bitwise the single-population
    `evolve.run` -- history AND every final state leaf."""
    cfg = nsga2.NSGA2Config(pop_size=pop)
    key = jax.random.PRNGKey(3)
    st_s, h_s = evolve.run(prob, "nsga2", cfg, key, n_gens)
    st_i, h_i = evolve.run(prob, "nsga2", cfg, key, n_gens,
                           islands=IslandConfig(1, 0))
    ok = np.array_equal(np.asarray(h_s), np.asarray(h_i)[:, 0])
    for a, b in zip(jax.tree.leaves(st_s), jax.tree.leaves(st_i)):
        ok = ok and np.array_equal(np.asarray(a), np.asarray(b)[0])
    return bool(ok)


def _gens_to_target(prob, cfg, islands, seed: int, budget: int,
                    target, gens_per_step: int):
    svc = PlacementService(prob, cfg, n_slots=1,
                           gens_per_step=gens_per_step, islands=islands)
    svc.submit_request(JobRequest(seed=seed, budget=budget,
                                  target=target))
    done = []
    while svc.active.any():
        done.extend(svc.step())
    return done[0]


def bench_islands(prob, pop: int, n_islands: int, migrate_every: int,
                  budget: int, gens_per_step: int) -> dict:
    """Within-job scaling: P islands per slot vs a single population.

    Both contestants chase the same combined-metric target (where a
    single population lands with ~2/3 of the budget) under EQUAL total
    evaluation budgets: the single-population job may run `budget` gens
    at pop evals each, the islands job `budget / P` gens at P x pop
    evals each.  Islands burn their evals in parallel -- P x the
    candidates per service step -- so they reach the target in fewer
    wallclock steps (`speedup_steps`).  `islands_single_compile` (an
    islands pool still compiles its batched step exactly once across
    rolling admission) and `islands_match_single_pop` (P=1 is bitwise
    the single-population run) are hard CI gates.
    """
    cfg = nsga2.NSGA2Config(pop_size=pop)
    match = _islands_match_single_pop(prob, pop)

    probe = _gens_to_target(prob, cfg, None, seed=123,
                            budget=(2 * budget) // 3, target=None,
                            gens_per_step=gens_per_step)
    target = float(probe.metric)
    single = _gens_to_target(prob, cfg, None, 0, budget, target,
                             gens_per_step)
    icfg = IslandConfig(n_islands, migrate_every)
    t0 = time.perf_counter()
    isl = _gens_to_target(prob, cfg, icfg, 0, max(budget // n_islands,
                                                  gens_per_step),
                          target, gens_per_step)
    wall_islands = time.perf_counter() - t0
    # a gens-to-target only counts if the target was actually reached
    # inside the budget -- exhausting the budget is not "reaching"
    single_hit = bool(single.metric <= target)
    islands_hit = bool(isl.metric <= target)

    # single-compile under rolling admission: more jobs than slots, each
    # with its own float hyperparameters, one islands pool
    svc = PlacementService(prob, cfg, n_slots=2,
                           gens_per_step=gens_per_step, islands=icfg)
    done = svc.run_jobs(make_job_specs(3, pop, 2 * gens_per_step, seed=55))
    single_compile = (len(done) == 3 and all(j.done for j in done)
                      and svc.step_compiles in (1, -1))
    return {
        "n_islands": n_islands, "migrate_every": migrate_every,
        "pop_size": pop, "budget_gens": budget,
        "gens_per_step": gens_per_step,
        "target_metric": target,
        "single_gens_to_target": single.gens,
        "islands_gens_to_target": isl.gens,
        "single_hit_target": single_hit,
        "islands_hit_target": islands_hit,
        "wall_s_islands": round(wall_islands, 4),
        "speedup_steps": round(single.gens / max(isl.gens, 1), 2),
        "islands_fewer_steps": bool(islands_hit and
                                    isl.gens < single.gens),
        "islands_single_compile": bool(single_compile),
        "islands_match_single_pop": match,
    }


def bench_kernels(prob, pop: int, reps: int = 40, timed_rounds: int = 12
                  ) -> dict:
    """Fused vs unfused evaluation at equal workload shape + differential
    correctness of the tiled kernel bodies on the problem's real extents.

    Throughput is the best of `timed_rounds` interleaved samples of `reps`
    jitted `evaluate_population` calls (post-compile, block_until_ready),
    reported at 3 significant figures -- run-to-run noise on a shared CI
    machine is well above 0.1%, so finer digits are spurious precision.
    On CPU both paths dispatch to the same ref-oracle composition and
    lower to the same XLA program (verified: identical fusion/while
    counts), so a best-sample gap below the ~3% measurement resolution is
    a tie and reports the pooled best for both paths instead of
    coin-flipping the ordering; a genuinely different path (the TPU
    Pallas kernel vs materialised intermediates) clears 3% trivially.
    Correctness runs the Pallas bodies in interpret mode against the
    `ref.py` oracles -- the same differential contract as
    `tests/test_fused_eval.py`, here on the real decode extents.
    """
    import jax.numpy as jnp

    from repro.core import genotype as G
    from repro.kernels import fused_eval as FE
    from repro.kernels import ref

    keys = jax.random.split(jax.random.PRNGKey(11), pop)
    popn = jax.vmap(lambda k: G.random_genotype(k, prob))(keys)

    # differential gates on the real extents
    bx, by = jax.vmap(lambda g: G.decode(prob, g))(popn)
    s, d = jnp.asarray(prob.net_src), jnp.asarray(prob.net_dst)
    w = jnp.asarray(prob.net_w)
    uidx = O.unit_index(prob)
    got = np.asarray(FE.fused_eval_pallas(bx, by, s, d, w, uidx,
                                          interpret=True))
    want = np.asarray(ref.fused_eval_ref(bx, by, s, d, w, uidx))
    fused_match_ref = bool(np.allclose(got, want, rtol=1e-5, atol=1e-6))
    objs = jnp.asarray(want)
    dom, cnt = FE.domination_counts_pallas(objs, interpret=True)
    dref = np.asarray(ref.domination_ref(objs))
    dom_counts_match_ref = bool(
        np.array_equal(np.asarray(dom.astype(bool)), dref)
        and np.array_equal(np.asarray(cnt), dref.astype(np.int32).sum(0)))

    def sample(fused: bool) -> float:
        t0 = time.perf_counter()
        for _ in range(reps):
            out = O.evaluate_population(prob, popn, fused)
        jax.block_until_ready(out)
        return time.perf_counter() - t0

    # warm both compiles, then interleave the timed rounds so clock/cache
    # drift on a busy CI machine cannot systematically favour whichever
    # path happens to be measured first
    jax.block_until_ready(O.evaluate_population(prob, popn, False))
    jax.block_until_ready(O.evaluate_population(prob, popn, True))
    best = {False: float("inf"), True: float("inf")}
    for _ in range(timed_rounds):
        for fused in (False, True):
            best[fused] = min(best[fused], sample(fused))
    pooled = min(best[False], best[True])
    if abs(best[True] - best[False]) / pooled < 0.03:
        best = {False: pooled, True: pooled}      # tie below resolution
    eps_unfused = float(f"{reps * pop / best[False]:.3g}")
    eps_fused = float(f"{reps * pop / best[True]:.3g}")
    return {
        "pop_size": pop,
        "n_nets": int(np.asarray(prob.net_src).shape[0]),
        "n_units": int(prob.n_units),
        "n_gids": int(bx.shape[-1]),
        "reps": reps,
        "evals_per_sec_fused": eps_fused,
        "evals_per_sec_unfused": eps_unfused,
        "fused_speedup": round(eps_fused / max(eps_unfused, 1e-9), 3),
        "fused_match_ref": fused_match_ref,
        "dom_counts_match_ref": dom_counts_match_ref,
    }


def bench_frontend(dev: str, n_clients: int, n_slots: int, pop: int,
                   budget: int, gens_per_step: int, max_queue: int) -> dict:
    """The asyncio front-end under concurrent load (`serve.frontend`).

    `n_clients` concurrent client coroutines each submit a
    `serve.api.JobRequest` and await its champion; with
    `max_queue < n_clients` part of the load experiences real
    backpressure.  Reports p50/p99 submit->champion latency and jobs/sec
    (post-compile: a warmup job compiles the pool's programs before the
    timed wave), plus `concurrent_match_sequential` -- every client's
    best objectives bitwise-match the same request set hand-pumped
    through a sequential scheduler, the determinism hard gate: the
    stepping thread and any admission interleaving change latency only,
    never results.
    """
    import asyncio

    from repro.serve.api import JobRequest
    from repro.serve.frontend import PlacementFrontend

    specs = make_job_specs(n_clients, pop, budget)
    reqs = [JobRequest(device=dev, cfg=s["cfg"], seed=s["seed"],
                       budget=s["budget"]) for s in specs]

    # sequential reference: same requests, hand-pumped scheduler
    seq = PlacementScheduler(n_slots=n_slots, gens_per_step=gens_per_step)
    jids = [seq.submit_request(r) for r in reqs]
    by_jid = {j.jid: j for j in seq.run_all()}
    ref = {r.seed: np.asarray(by_jid[j].result.best_objs)
           for r, j in zip(reqs, jids)}

    async def run():
        sched = PlacementScheduler(n_slots=n_slots,
                                   gens_per_step=gens_per_step)
        lat: list = []

        async def client(req):
            t0 = time.perf_counter()
            handle = await fe.submit(req)
            pj = await handle.wait()
            lat.append(time.perf_counter() - t0)
            return req.seed, np.asarray(pj.best_objs)

        async with PlacementFrontend(sched, max_queue=max_queue) as fe:
            # warmup: the pool's init/step programs compile here, so the
            # timed wave measures serving latency, not XLA
            warm = await fe.submit(JobRequest(
                device=dev, cfg=specs[0]["cfg"], seed=10_000,
                budget=gens_per_step))
            await warm.wait()
            t0 = time.perf_counter()
            results = await asyncio.gather(*[client(r) for r in reqs])
            wall = time.perf_counter() - t0
            stats = fe.stats()
        return dict(results), lat, wall, stats

    got, lat, wall, stats = asyncio.run(run())
    match = all(np.array_equal(ref[s], got[s]) for s in ref)
    p50, p99 = np.percentile(np.array(lat) * 1e3, [50, 99])
    (pool_stats,) = stats["fleet"]["pools"].values()
    return {
        "n_clients": n_clients, "n_slots": n_slots,
        "max_queue": max_queue, "pop_size": pop, "budget_gens": budget,
        "gens_per_step": gens_per_step,
        "wall_s": round(wall, 4),
        "jobs_per_sec": round(n_clients / wall, 3),
        "submit_to_champion_p50_ms": round(float(p50), 2),
        "submit_to_champion_p99_ms": round(float(p99), 2),
        "backpressure_waits": stats["backpressure_waits"],
        "step_compiles": pool_stats["step_compiles"],
        "concurrent_match_sequential": bool(match),
    }


def bench_telemetry(dev: str, n_clients: int, n_slots: int, pop: int,
                    budget: int, gens_per_step: int, max_queue: int,
                    rounds: int = 3) -> dict:
    """Observability overhead: tracing OFF vs ON on the front-end path.

    Interleaved best-of-`rounds` waves (OFF, ON, OFF, ON, ...) so clock
    and cache drift cannot systematically favour one configuration.  The
    OFF waves run the default serving configuration -- instrumented
    modules, tracing disabled -- and produce `jobs_per_sec_off`, the
    number `check_bench` hard-gates at 2% against the committed baseline:
    a single mispredicted branch per event site is the entire budget.
    The ON waves record into the in-memory span ring (no sink -- disk
    flushing is an exporter cost, not an instrumentation cost) and must
    reconcile exactly: one `job.submit` and exactly one terminal event
    per job, every round (`trace_events_complete`).
    """
    import asyncio

    from repro.serve.frontend import PlacementFrontend
    from repro.serve import tracing

    specs = make_job_specs(n_clients, pop, budget)

    def wave() -> float:
        async def run():
            sched = PlacementScheduler(n_slots=n_slots,
                                       gens_per_step=gens_per_step)

            async def client(req):
                handle = await fe.submit(req)
                await handle.wait()

            async with PlacementFrontend(sched, max_queue=max_queue) as fe:
                # warmup inside the wave: the pool's programs land in the
                # in-memory jit cache before the timed gather
                warm = await fe.submit(JobRequest(
                    device=dev, cfg=specs[0]["cfg"], seed=10_000,
                    budget=gens_per_step))
                await warm.wait()
                reqs = [JobRequest(device=dev, cfg=s["cfg"], seed=s["seed"],
                                   budget=s["budget"]) for s in specs]
                t0 = time.perf_counter()
                await asyncio.gather(*[client(r) for r in reqs])
                return time.perf_counter() - t0
        return asyncio.run(run())

    was_enabled = tracing.enabled()
    best = {"off": float("inf"), "on": float("inf")}
    events_complete = True
    try:
        for _ in range(rounds):
            tracing.disable(close_sinks=False)
            best["off"] = min(best["off"], wave())
            tracing.enable()
            tracing.tracer().clear()
            best["on"] = min(best["on"], wave())
            evs = tracing.tracer().events()
            submits = sum(ev.name == "job.submit" for ev in evs)
            terminals = sum(ev.name in tracing.TERMINAL_EVENTS
                            for ev in evs)
            # + 1: the warmup job is traced too and must terminate
            events_complete = (events_complete
                               and submits == n_clients + 1
                               and terminals == submits)
    finally:
        tracing.tracer().clear()
        if not was_enabled:
            tracing.disable(close_sinks=False)
    return {
        "n_clients": n_clients, "n_slots": n_slots,
        "max_queue": max_queue, "pop_size": pop, "budget_gens": budget,
        "gens_per_step": gens_per_step, "rounds": rounds,
        "jobs_per_sec_off": round(n_clients / best["off"], 3),
        "jobs_per_sec_on": round(n_clients / best["on"], 3),
        "enabled_overhead_pct": round(
            (best["on"] / max(best["off"], 1e-9) - 1.0) * 100, 2),
        "trace_events_complete": bool(events_complete),
    }


def bench_compile(cache_dir: str = None, pop: int = 16, n_slots: int = 8,
                  gens_per_step: int = 8, budget: int = 8,
                  device: str = "xcvu_test", grow_to: int = 16) -> dict:
    """Cold vs cache-restored cold start, measured in fresh subprocesses.

    In-memory jit caches die with a process, so each leg runs
    `benchmarks.compile_probe` as its own interpreter against a shared
    persistent-cache directory: leg 1 (cold) fills it, leg 2 (warm) must
    answer every compile request from it.  With no `cache_dir` given a
    fresh temporary directory is used (the committed-baseline mode: the
    cold leg is deterministically cold); CI's compile-budget job passes
    its own directory the same way.
    """
    import os
    import shutil
    import subprocess
    import sys
    import tempfile

    fresh = cache_dir is None
    if fresh:
        cache_dir = tempfile.mkdtemp(prefix="repro-compile-bench-")

    def leg() -> dict:
        cmd = [sys.executable, "-m", "benchmarks.compile_probe",
               "--cache-dir", cache_dir, "--pop", str(pop),
               "--slots", str(n_slots), "--gps", str(gens_per_step),
               "--budget", str(budget), "--device", device,
               "--grow-to", str(grow_to)]
        out = subprocess.run(cmd, check=True, capture_output=True,
                             text=True, env=dict(os.environ))
        # last stdout line is the JSON object (jax may log above it)
        return json.loads(out.stdout.strip().splitlines()[-1])

    try:
        cold = leg()
        warm = leg()
    finally:
        if fresh:
            shutil.rmtree(cache_dir, ignore_errors=True)
    speedup = cold["ttfg_ms"] / max(warm["ttfg_ms"], 1e-9)
    return {
        "pop_size": pop, "n_slots": n_slots, "gens_per_step": gens_per_step,
        "budget_gens": budget, "grow_to": grow_to, "device": device,
        "cache_salt": cold["cache_salt"],
        "ttfg_cold_ms": cold["ttfg_ms"],
        "ttfg_warm_ms": warm["ttfg_ms"],
        "ttfg_speedup": round(speedup, 2),
        "compiles_cold": cold["compiles"],
        "recompiles_cold": cold["recompiles"],
        "compile_secs_cold": cold["compile_secs"],
        "compiles_warm": warm["compiles"],
        "recompiles_warm": warm["recompiles"],
        "cache_hits_warm": warm["cache_hits"],
        "compile_secs_warm": warm["compile_secs"],
        "recompiles_warm_zero": bool(warm["recompiles"] == 0),
        "warm_ttfg_5x": bool(speedup >= 5.0),
    }


def main(out: str = "BENCH_placement.json", mode: str = "quick",
         compile_cache_dir: str = None) -> dict:
    """mode: smoke (CI PR gate) < quick (default) < full (paper-scale)."""
    smoke, full = mode == "smoke", mode == "full"
    dev = "xcvu11p" if full else "xcvu_test"
    prob = common.problem(dev)
    service = bench_service(
        prob,
        n_jobs=8 if smoke else (16 if not full else 64),
        n_slots=8, pop=16 if not full else 64,
        budget=8 if smoke else (16 if not full else 96),
        gens_per_step=8)
    pf = bench_portfolio(prob, n_cfgs=4 if not full else 8,
                         pop=16 if not full else 64,
                         n_gens=8 if smoke else (16 if not full else 100))
    # base_gens does NOT shrink in smoke mode: the migrated champion must
    # be converged for warm_beats_cold to be a meaningful (and stable)
    # assertion -- an under-trained seed migrates worse than random init.
    tr = bench_transfer(
        base_dev="xcvu3p" if full else "xcvu_test",
        dst_dev="xcvu5p" if full else "xcvu_test2",
        base_pop=32, base_gens=120 if full else 100,
        pop=16, budget=80 if full else (40 if smoke else 60),
        gens_per_step=2)
    sched = bench_scheduler(
        devices=("xcvu3p", "xcvu5p") if full else ("xcvu_test",
                                                   "xcvu_test2"),
        pops=(8, 16), jobs_per_pool=2 if smoke else 4,
        budget=8 if smoke else 16, n_slots=2, gens_per_step=4)
    # cache budgets mirror `transfer` (same sibling-pair race, now driven
    # by the store): the cold leg must genuinely converge toward the
    # migrated champion for the 1/4-gens sibling assertion to be stable
    cache = bench_cache(
        base_dev="xcvu3p" if full else "xcvu_test",
        sib_dev="xcvu5p" if full else "xcvu_test2",
        pop=16, budget=80 if full else (40 if smoke else 60),
        gens_per_step=2)
    pol = bench_policy(dev, budget=8 if smoke else 16, gens_per_step=4)
    autoscale = bench_autoscale(
        dev, n_jobs=6 if not full else 12, pop=16 if not full else 64,
        budget=8 if smoke else 16, gens_per_step=4)
    # the islands budget does NOT shrink in smoke mode (same reasoning as
    # `transfer`): the single-population contestant must genuinely reach
    # the probe target inside its budget for gens-to-target to mean
    # anything -- 48 gens is the verified-convergent smoke/quick config
    isl = bench_islands(
        prob, pop=16 if not full else 32,
        n_islands=4 if not full else 8, migrate_every=2,
        budget=48 if not full else 96, gens_per_step=2)
    kern = bench_kernels(prob, pop=64 if not full else 256,
                         reps=40 if smoke else 60)
    # 32 concurrent clients in EVERY mode (the serving-contract load the
    # ROADMAP names); only budgets shrink in smoke
    fe = bench_frontend(
        dev, n_clients=32, n_slots=8, pop=16,
        budget=8 if smoke else (16 if not full else 64),
        gens_per_step=4, max_queue=16)
    # telemetry shape stays fixed across smoke/quick (only full widens the
    # budget): the 2% disabled-overhead gate only fires at an identical
    # workload shape, so a stable shape keeps the gate armed in CI
    te = bench_telemetry(
        dev, n_clients=16, n_slots=8, pop=16,
        budget=8 if not full else 16, gens_per_step=4, max_queue=16)
    # shapes deliberately do NOT scale with mode: the compile bill depends
    # on the program set, not the budgets, and a fixed shape keeps the
    # cold/warm numbers comparable across smoke / quick / full reports
    comp = bench_compile(cache_dir=compile_cache_dir)
    report = {
        "bench": "placement_service",
        "created_unix": int(time.time()),
        "mode": mode,
        "device": dev,
        "jax_version": jax.__version__,
        "backend": jax.default_backend(),
        "service": service,
        "portfolio": pf,
        "transfer": tr,
        "scheduler": sched,
        "cache": cache,
        "policy": pol,
        "autoscale": autoscale,
        "islands": isl,
        "kernels": kern,
        "frontend": fe,
        "telemetry": te,
        "compile": comp,
    }
    with open(out, "w") as f:
        json.dump(report, f, indent=2)
        f.write("\n")
    print(json.dumps(report, indent=2))
    print(f"\nwrote {out}")
    return report


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="smallest budgets (CI PR gate)")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--out", default="BENCH_placement.json")
    ap.add_argument("--compile-cache-dir", default=None,
                    help="persistent-cache directory for the compile "
                         "section's probe pair (default: a fresh temp dir, "
                         "so the cold leg is deterministically cold)")
    args = ap.parse_args()
    if args.smoke and args.full:
        ap.error("--smoke and --full are mutually exclusive")
    main(out=args.out,
         mode="smoke" if args.smoke else ("full" if args.full else "quick"),
         compile_cache_dir=args.compile_cache_dir)
