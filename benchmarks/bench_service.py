"""Placement service + portfolio throughput -> BENCH_placement.json.

    PYTHONPATH=src python -m benchmarks.bench_service [--full] [--out PATH]

First point on the serving-perf trajectory.  Two measurements:

  * **service**: the continuous-batching placement engine runs >= 8
    concurrent jobs batched into one compiled step; reports jobs/sec,
    generations/sec (active-slot generations actually served) and
    candidate evaluations/sec (gens x pop), all measured after the single
    step compile.
  * **portfolio**: >= 4 hyperparameter configs run as ONE vmapped jitted
    program (`core.portfolio.run_portfolio`); verifies the champion and
    every per-member best match equivalent independent `evolve.run` calls,
    and reports the batched-vs-sequential speedup (both post-compile).

JSON contract (consumed by future trend tooling -- keep keys stable):
  bench, created_unix, device, jax_version, backend,
  service.{n_slots,n_jobs,pop_size,budget_gens,gens_per_step,wall_s,
           jobs_per_sec,gens_per_sec,evals_per_sec,step_compiles},
  portfolio.{n_configs,n_gens,pop_size,wall_s_batched,wall_s_independent,
             speedup,champion_matches,members_match}
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from benchmarks import common
from repro.core import evolve, nsga2, objectives as O, portfolio
from repro.serve.placement_service import PlacementService, make_job_specs


def bench_service(prob, n_jobs: int, n_slots: int, pop: int, budget: int,
                  gens_per_step: int) -> dict:
    base = nsga2.NSGA2Config(pop_size=pop)
    svc = PlacementService(prob, base, n_slots=n_slots,
                           gens_per_step=gens_per_step)

    # warmup: compiles the init + step programs (one job is enough)
    svc.run_jobs(make_job_specs(1, pop, budget, seed=99))
    svc.useful_gens, svc.total_steps = 0, 0

    t0 = time.perf_counter()
    done = svc.run_jobs(make_job_specs(n_jobs, pop, budget))
    wall = time.perf_counter() - t0
    assert len(done) == n_jobs and all(j.done for j in done)
    s = svc.stats()
    return {
        "n_slots": n_slots, "n_jobs": n_jobs, "pop_size": pop,
        "budget_gens": budget, "gens_per_step": gens_per_step,
        "wall_s": round(wall, 4),
        "jobs_per_sec": round(n_jobs / wall, 3),
        "gens_per_sec": round(s["useful_gens"] / wall, 2),
        "evals_per_sec": round(s["useful_gens"] * pop / wall, 1),
        "step_compiles": s["step_compiles"],
    }


def bench_portfolio(prob, n_cfgs: int, pop: int, n_gens: int) -> dict:
    etas = np.linspace(5.0, 25.0, n_cfgs)
    muts = np.linspace(0.05, 0.3, n_cfgs)
    cfgs = [nsga2.NSGA2Config(pop_size=pop, sbx_eta=float(e),
                              real_mut_prob=float(m))
            for e, m in zip(etas, muts)]
    keys = jax.random.split(jax.random.PRNGKey(7), n_cfgs)

    # batched: warmup compile, then timed steady-state call
    portfolio.run_portfolio(prob, "nsga2", cfgs, keys=keys, n_gens=n_gens)
    t0 = time.perf_counter()
    res = portfolio.run_portfolio(prob, "nsga2", cfgs, keys=keys,
                                  n_gens=n_gens)
    wall_batched = time.perf_counter() - t0

    # independent references (same keys): warmup each, then timed
    ind_best = []
    wall_ind = 0.0
    for cfg, k in zip(cfgs, keys):
        evolve.run(prob, "nsga2", cfg, k, n_gens)          # compile
        dt, (st, _) = common.timed(evolve.run, prob, "nsga2", cfg, k, n_gens)
        wall_ind += dt
        ind_best.append(np.asarray(evolve.state_best_objs(st)))
    ind_best = np.stack(ind_best)
    members_match = bool(np.allclose(res.best_objs, ind_best, rtol=1e-5))
    ind_champ = int(np.argmin(O.combined_metric(ind_best)))
    return {
        "n_configs": n_cfgs, "n_gens": n_gens, "pop_size": pop,
        "wall_s_batched": round(wall_batched, 4),
        "wall_s_independent": round(wall_ind, 4),
        "speedup": round(wall_ind / max(wall_batched, 1e-9), 2),
        "champion_matches": bool(res.champion == ind_champ),
        "members_match": members_match,
    }


def main(quick: bool = True, out: str = "BENCH_placement.json") -> dict:
    dev = "xcvu_test" if quick else "xcvu11p"
    prob = common.problem(dev)
    service = bench_service(
        prob,
        n_jobs=16 if quick else 64,
        n_slots=8, pop=16 if quick else 64,
        budget=16 if quick else 96,        # multiples of gens_per_step
        gens_per_step=8)
    pf = bench_portfolio(prob, n_cfgs=4 if quick else 8,
                         pop=16 if quick else 64,
                         n_gens=16 if quick else 100)
    report = {
        "bench": "placement_service",
        "created_unix": int(time.time()),
        "device": dev,
        "jax_version": jax.__version__,
        "backend": jax.default_backend(),
        "service": service,
        "portfolio": pf,
    }
    with open(out, "w") as f:
        json.dump(report, f, indent=2)
        f.write("\n")
    print(json.dumps(report, indent=2))
    print(f"\nwrote {out}")
    return report


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--out", default="BENCH_placement.json")
    args = ap.parse_args()
    main(quick=not args.full, out=args.out)
